"""CI recovery-smoke gate: kill -9 a checkpointing materialization at a
seeded round, resume it in a fresh process, and require EXACT closure
parity plus a genuinely partial resume (``resumed_rounds <
total_rounds`` — a resume that silently redid the whole run from round
one would also pass a parity-only gate).

Two legs, both over the same random-augmented chain TC instance:

* ``fused``  — single-device fused executor, SIGKILL mid-fixpoint under a
  forced-overflow storm, resume on the same executor.
* ``dist``   — 4-shard distributed run crashed the same way, resumed
  ELASTICALLY on a 2-device mesh (the checkpoint is mesh-neutral; the
  restoring run re-partitions by the exchange hash).  The leg also
  checks the per-round host-pull invariant holds after restore.

Writes ``RECOVERY_smoke.json`` at the repo root and exits nonzero if any
leg fails.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_WORKLOAD = """
    import numpy as np
    from repro.core.terms import parse_atom, parse_program
    from repro.engine.materialize import EngineKB, materialize

    TC = parse_program("e(X, Y) -> T(X, Y)\\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    rng = np.random.default_rng(5)
    edges = [(i, i + 1) for i in range(80)]
    edges += [tuple(e) for e in rng.integers(0, 80, (30, 2))]
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]
"""

CRASH = textwrap.dedent("""
    import sys
    sys.path.insert(0, %r)
""" % SRC) + textwrap.dedent(_WORKLOAD) + textwrap.dedent("""
    kb = EngineKB(TC, B)
    materialize(kb, mode="tg")
    print("SURVIVED")
""")

RESUME = textwrap.dedent("""
    import os, sys, json
    xla = os.environ.pop("RESUME_XLA_FLAGS", "")
    if xla:
        os.environ["XLA_FLAGS"] = xla
    sys.path.insert(0, %r)
    ckpt = os.environ.pop("REPRO_CKPT_DIR")
""" % SRC) + textwrap.dedent(_WORKLOAD) + textwrap.dedent("""
    from repro.engine import ops

    ref = EngineKB(TC, B)                   # checkpoint env popped: clean run
    st_ref = materialize(ref, mode="tg")

    os.environ["REPRO_CKPT_DIR"] = ckpt
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg")
    s = ops.HOST_SYNC_STATS.snapshot()
    resumed = st.extra.get("resumed_rounds", 0)
    out = {
        "parity": kb.decode_facts() == ref.decode_facts(),
        "resumed_rounds": resumed, "rounds": st.rounds,
        "ref_rounds": st_ref.rounds,
        "resumed_from": list(st.extra.get("resumed_from", ())),
    }
    if st.extra.get("dist"):
        out["pulls_invariant"] = s.dist_pulls == (
            (st.rounds - resumed - s.dist_fixpoint_iters)
            + s.dist_retries + s.dist_fixpoint_pulls)
    print(json.dumps(out))
""")


def _run(script, env, timeout=600):
    full = {**os.environ}
    full.pop("REPRO_FAULT_SPEC", None)
    full.pop("REPRO_CKPT_DIR", None)
    full.update(env)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=timeout,
                          env=full)


def run_leg(name: str, base_env: dict, resume_env: dict) -> dict:
    leg = {"leg": name, "ok": False}
    with tempfile.TemporaryDirectory(prefix=f"recovery_{name}_") as ckpt:
        env = {**base_env, "REPRO_CKPT_DIR": ckpt, "REPRO_CKPT_KEEP": "100"}
        t0 = time.perf_counter()
        crash = _run(CRASH,
                     {**env, "REPRO_FAULT_SPEC": "storm,crash:round=4"})
        leg["crash_returncode"] = crash.returncode
        leg["crash_s"] = round(time.perf_counter() - t0, 2)
        if crash.returncode != -signal.SIGKILL or "SURVIVED" in crash.stdout:
            leg["error"] = ("crash run did not die by SIGKILL: "
                            f"rc={crash.returncode} "
                            f"stderr={crash.stderr[-1500:]}")
            return leg
        tags = [d for d in os.listdir(ckpt) if d.startswith("ckpt_")]
        leg["checkpoints_left"] = len(tags)
        if not tags:
            leg["error"] = "crash left no durable checkpoint behind"
            return leg

        t0 = time.perf_counter()
        res = _run(RESUME, {**env, **resume_env})
        leg["resume_s"] = round(time.perf_counter() - t0, 2)
        if res.returncode != 0:
            leg["error"] = f"resume run failed: {res.stderr[-1500:]}"
            return leg
        out = json.loads(res.stdout.strip().splitlines()[-1])
        leg.update(out)
        checks = [
            ("parity", out.get("parity") is True),
            ("partial resume", 1 <= out.get("resumed_rounds", 0)
             < out.get("rounds", 0)),
            ("round parity", out.get("rounds") == out.get("ref_rounds")),
        ]
        if "pulls_invariant" in out:
            checks.append(("pulls invariant", out["pulls_invariant"]))
        failed = [c for c, ok in checks if not ok]
        if failed:
            leg["error"] = f"gate failed: {failed}"
            return leg
        leg["ok"] = True
        return leg


def main() -> int:
    legs = [
        run_leg("fused", {"REPRO_FUSED": "1"}, {}),
        run_leg(
            "dist",
            {"REPRO_DIST": "1",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=4"},
            # the resume script re-applies this AFTER popping the crash
            # run's 4-device forcing: elastic restore onto 2 devices
            {"XLA_FLAGS": "",
             "RESUME_XLA_FLAGS":
                 "--xla_force_host_platform_device_count=2"}),
    ]
    payload = {"ok": all(l["ok"] for l in legs), "legs": legs}
    with open("RECOVERY_smoke.json", "w") as f:
        json.dump(payload, f, indent=2)
    for leg in legs:
        status = "ok" if leg["ok"] else f"FAILED ({leg.get('error')})"
        print(f"[recovery-smoke] {leg['leg']}: {status}")
    return 0 if payload["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
