"""Quickstart: the paper's Example 1, end to end.

    PYTHONPATH=src python examples/quickstart.py

Builds the P1 program, runs the chase, computes the instance-independent TG
with Algorithm 1, minimizes it (Fig. 1(b) -> Fig. 1(c)), reasons over it, and
runs the same program through the vectorized engine.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.chase import chase
from repro.core.eg import evaluate, is_tg_for
from repro.core.terms import example1_program, parse_atom
from repro.core.tg_linear import min_linear, tglinear
from repro.engine.materialize import EngineKB, materialize


def main():
    P = example1_program()
    B = [parse_atom("r(c1, c2)")]
    print("program P1:")
    print(P)
    print("\nbase instance:", B)

    ch = chase(P, B, variant="restricted")
    print(f"\n[chase]   rounds={ch.rounds} triggers={ch.triggers} "
          f"derived={ch.derived}")
    for f in sorted(map(str, ch.facts)):
        print("   ", f)

    G1 = tglinear(P)
    print(f"\n[tglinear] G1: {G1.stats()}  (Figure 1(b))")
    G2 = min_linear(G1)
    print(f"[minLinear] G2: {G2.stats()}  (Figure 1(c))")
    assert is_tg_for(G2, P, B)

    ev = evaluate(G2, B)
    print(f"[TG-guided reasoning] triggers={ev.triggers} "
          f"(vs chase {ch.triggers})")
    for f in sorted(map(str, ev.facts)):
        print("   ", f)

    # vectorized engine on a bigger instance
    B_big = [parse_atom(f"r(a{i}, b{i})") for i in range(1000)]
    kb = EngineKB(P, B_big)
    st = materialize(kb, mode="tg_linear", tg_eg=G2)
    print(f"\n[engine tg_linear] base={len(B_big)} derived={st.derived} "
          f"triggers={st.triggers}")


if __name__ == "__main__":
    main()
