"""End-to-end driver: materialize a KB with the TG engine, linearize the
derived facts into token sequences, and train a ~100M-parameter LM on them
for a few hundred steps (with checkpoint/restart).

    PYTHONPATH=src python examples/kb_to_lm.py [--steps 200]
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ModelConfig
from repro.data.kb_sources import LUBM_L, lubm_facts
from repro.data.pipeline import KBLinearizer
from repro.engine.materialize import EngineKB, materialize
from repro.launch.mesh import compat_make_mesh
from repro.models import model as M
from repro.models.layers import MeshCtx
from repro.train.train_loop import train


def lm_100m(vocab: int) -> ModelConfig:
    return ModelConfig(
        name="kb-lm-100m", family="dense", num_layers=8, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2304,
        vocab_size=vocab, mlp_type="swiglu", norm_type="rmsnorm",
        attn_chunk=128, loss_chunk=128, remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    # 1) materialize the KB (paper's technique)
    print("[kb] materializing LUBM-L ...")
    kb = EngineKB(LUBM_L, lubm_facts(n_univ=4))
    st = materialize(kb, mode="tg")
    print(f"[kb] derived={st.derived} triggers={st.triggers} "
          f"total={kb.num_facts()} facts")

    # 2) linearize derived facts into a token stream
    data = KBLinearizer(kb, batch=args.batch, seq=args.seq)
    print(f"[data] vocab={data.vocab_size} stream={len(data.stream)} tokens")

    # 3) train the LM
    cfg = lm_100m(data.vocab_size).with_(num_layers=args.layers)
    n = cfg.param_counts()["total"]
    print(f"[model] {n/1e6:.1f}M params")
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    mcx = MeshCtx(mesh=mesh, dp=("data",), tp="model")
    mdl = M.build(cfg, mcx)
    ckpt = args.ckpt or os.path.join(tempfile.gettempdir(), "kb_lm_ckpt")
    params, opt, losses = train(mdl, data, steps=args.steps, ckpt_dir=ckpt,
                                ckpt_every=100, log_every=10)
    first, last = losses[0][1], losses[-1][1]
    print(f"[done] loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
