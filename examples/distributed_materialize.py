"""Distributed TG materialization demo (beyond-paper): arbitrary Datalog
programs — transitive closure, LUBM-L, and the rho-df RDFS subset — over
hash-partitioned facts on 8 simulated devices, via the same rule-plan IR
the single-device executors run.

    python examples/distributed_materialize.py

Long runs survive preemption: set ``REPRO_CKPT_DIR=/some/dir`` and every
executor checkpoints at phase boundaries and resumes from the newest
valid checkpoint — even at a *different* device count (the restore
re-partitions by the exchange hash). See README "Fault tolerance &
recovery".
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.terms import parse_atom, parse_program
from repro.data.kb_sources import LUBM_L, RHO_DF, lubm_facts, rho_df_facts
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize


def tc_scenario():
    rng = np.random.default_rng(0)
    edges = np.unique(rng.integers(0, 120, (600, 2)).astype(np.int32), axis=0)
    P = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    return P, [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


def main():
    scenarios = [
        ("TC", *tc_scenario()),
        ("LUBM-L", LUBM_L, lubm_facts(n_univ=1)),
        ("rho-df", RHO_DF, rho_df_facts(n_classes=15, n_props=6,
                                        n_instances=80)),
    ]
    for name, P, B in scenarios:
        # single-device tg reference
        ref = EngineKB(P, B)
        materialize(ref, mode="tg")
        # sharded executor over all 8 forced host devices
        ops.HOST_SYNC_STATS.reset()
        kb = EngineKB(P, B)
        st = materialize(kb, mode="tg", backend="dist")
        print(f"[dist] {name}: {len(B)} base facts over "
              f"{st.extra['ndev']} shards -> {kb.num_facts()} facts in "
              f"{st.rounds} rounds ({st.triggers} triggers, "
              f"{ops.HOST_SYNC_STATS.dist_pulls} host pulls, "
              f"{ops.HOST_SYNC_STATS.dist_retries} capacity retries)")
        assert kb.decode_facts() == ref.decode_facts(), name
        print(f"[dist] {name}: verified against the single-device tg "
              f"executor ({ref.num_facts()} facts)")


if __name__ == "__main__":
    main()
