"""Distributed TG materialization demo (beyond-paper): hash-partitioned
facts, all_to_all repartition joins, psum convergence — on 8 simulated
devices.

    python examples/distributed_materialize.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.engine.distributed import DistConfig, run_distributed_tc
from repro.launch.mesh import compat_make_mesh


def main():
    rng = np.random.default_rng(0)
    edges = np.unique(rng.integers(0, 300, (2000, 2)).astype(np.int32),
                      axis=0)
    mesh = compat_make_mesh((8, 1), ("data", "model"))
    cfg = DistConfig(shard_cap=1 << 15, delta_cap=1 << 13, bucket_cap=1 << 11)
    print(f"[dist] {len(edges)} edges over {mesh.shape['data']} shards")
    t_store, count, triggers, rounds = run_distributed_tc(edges, mesh, cfg)
    print(f"[dist] closure={count} facts rounds={rounds} triggers={triggers}")

    # single-shard oracle
    from collections import defaultdict
    adj = defaultdict(set)
    for a, b in edges:
        adj[a].add(b)
    closure = set(map(tuple, edges))
    frontier = set(closure)
    while frontier:
        new = set()
        for (x, y) in frontier:
            for z in adj[y]:
                if (x, z) not in closure:
                    new.add((x, z))
        closure |= new
        frontier = new
    assert count == len(closure), (count, len(closure))
    print(f"[dist] verified against host oracle ({len(closure)} facts)")


if __name__ == "__main__":
    main()
