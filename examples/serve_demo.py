"""Serving demo: prefill + batched greedy decode with KV caches on a small
dense LM (the serve-side public API).

    PYTHONPATH=src python examples/serve_demo.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_smoke_config
from repro.launch.mesh import compat_make_mesh
from repro.models import model as M
from repro.models.layers import MeshCtx


def main():
    cfg = get_smoke_config("stablelm_12b").with_(dtype="float32")
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    mcx = MeshCtx(mesh=mesh, dp=("data",), tp="model")
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(0))

    B, S, gen = 4, 24, 16
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(mdl.prefill_step)
    decode = jax.jit(mdl.decode_step)

    tok, caches = prefill(params, {"tokens": prompts})
    out = [np.asarray(tok)]
    for t in range(gen - 1):
        tok, caches = decode(params, caches, tok,
                             jnp.asarray(S + t, jnp.int32))
        out.append(np.asarray(tok))
    gen_tokens = np.stack(out, axis=1)
    print(f"[serve] prompts {prompts.shape} -> generated {gen_tokens.shape}")
    for b in range(B):
        print(f"  seq{b}: {gen_tokens[b][:12]} ...")
    print("[serve] ok")


if __name__ == "__main__":
    main()
