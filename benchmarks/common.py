"""Shared benchmark utilities: timing, memory, CSV emit + JSON recording."""
from __future__ import annotations

import os
import resource
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# every emit() row also lands here so drivers (benchmarks/run.py --smoke)
# can dump machine-readable BENCH_*.json files
RESULTS: list[dict] = []


def reset_results() -> None:
    RESULTS.clear()


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)


def emit(name: str, seconds: float, derived: int, **extra):
    """CSV row: name,us_per_call,derived[,k=v...]

    Every row carries a ``peak_rss_mb`` column (process high-water by
    default); benches that measure a subprocess pass their own value."""
    extra.setdefault("peak_rss_mb", round(peak_rss_mb(), 1))
    cols = [name, f"{seconds * 1e6:.0f}", str(derived)]
    cols += [f"{k}={v}" for k, v in extra.items()]
    print(",".join(cols), flush=True)
    RESULTS.append({"name": name, "us_per_call": round(seconds * 1e6),
                    "derived": derived, **extra})


def warmup(program, base, modes=("seminaive", "tg_noopt", "tg"), **kw):
    """Run a small instance through every mode so jit compilation (per
    capacity bucket) is paid before timing."""
    from repro.engine.materialize import EngineKB, materialize
    for mode in modes:
        kb = EngineKB(program, base)
        materialize(kb, mode=mode, **kw)
