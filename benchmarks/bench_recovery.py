"""Fault-tolerance cost table — the trajectory behind
``BENCH_recovery.json``.

The claim under test: round/phase-boundary checkpointing is cheap enough
to leave on for long materializations, and resuming does not redo work.
Per scenario (deep-chain TC and wide random-graph TC):

* ``recovery.<scen>.baseline`` — steady-state fused materialization with
  checkpointing disabled: the wall-clock floor.
* ``recovery.<scen>.ckpt``     — the same run saving a durable checkpoint
  at EVERY boundary (``REPRO_CKPT_EVERY=1``, the most conservative
  setting): reports the checkpoint count, the bytes of the final
  checkpoint directory, and ``overhead_frac`` vs the baseline.
* ``recovery.<scen>.resume``   — the checkpoint store rewound to a
  mid-run tag, resumed by a fresh KB: reports ``resumed_rounds``,
  ``redone_rounds`` (total - resumed: the work a crash actually costs),
  restore-to-done wall, and fact parity with the uninterrupted run.

Rows carry ``parity``/round counters as deterministic gates; wall times
are machine-dependent trajectory data."""
from __future__ import annotations

import os
import tempfile
import time

from benchmarks.common import emit
from repro.data.kb_sources import TC, tc_chain_facts, tc_random_facts
from repro.engine import recovery
from repro.engine.materialize import EngineKB, materialize


def _dir_bytes(d: str) -> int:
    total = 0
    for root, _, files in os.walk(d):
        total += sum(os.path.getsize(os.path.join(root, f)) for f in files)
    return total


def _timed_run(P, B, **env):
    saved = {k: os.environ.get(k) for k in env}
    os.environ.update({k: v for k, v in env.items() if v is not None})
    for k, v in env.items():
        if v is None:
            os.environ.pop(k, None)
    try:
        kb = EngineKB(P, B)
        t0 = time.perf_counter()
        st = materialize(kb, mode="tg")
        return kb, st, time.perf_counter() - t0
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _scenario(name: str, P, B) -> None:
    os.environ.setdefault("REPRO_FUSED", "1")
    # warm until the capacity memo stops moving (a moving plan means the
    # next run recompiles), so the timed runs are steady-state
    from repro.engine import plan
    prev = None
    for _ in range(5):
        _timed_run(P, B, REPRO_CKPT_DIR=None)
        snap = sorted((str(k), v) for k, v in plan._CAP_MEMO.items())
        if snap == prev:
            break
        prev = snap
    ref_kb, ref_st, base_s = _timed_run(P, B, REPRO_CKPT_DIR=None)
    emit(f"recovery.{name}.baseline", base_s, ref_st.derived,
         rounds=ref_st.rounds)

    with tempfile.TemporaryDirectory(prefix=f"bench_recovery_{name}_") as d:
        kb, st, ckpt_s = _timed_run(P, B, REPRO_CKPT_DIR=d,
                                    REPRO_CKPT_KEEP="1000",
                                    REPRO_CKPT_EVERY="1")
        mgr = recovery.RecoveryManager(d, keep=1000)
        tags = mgr.tags()
        emit(f"recovery.{name}.ckpt", ckpt_s, st.derived,
             rounds=st.rounds, checkpoints=st.extra.get("checkpoints", 0),
             ckpt_bytes=_dir_bytes(mgr._path(tags[-1])) if tags else 0,
             overhead_frac=round(ckpt_s / base_s - 1.0, 3) if base_s else 0,
             parity=kb.decode_facts() == ref_kb.decode_facts())

        mid = tags[len(tags) // 2] if len(tags) > 1 else tags[-1]
        for t in tags:
            if t > mid:
                mgr.drop(t)
        kb2, st2, resume_s = _timed_run(P, B, REPRO_CKPT_DIR=d)
        resumed = st2.extra.get("resumed_rounds", 0)
        emit(f"recovery.{name}.resume", resume_s, st2.derived,
             rounds=st2.rounds, resumed_rounds=resumed,
             redone_rounds=st2.rounds - resumed,
             parity=kb2.decode_facts() == ref_kb.decode_facts())


def run(smoke: bool = False) -> None:
    n_chain = 64 if smoke else 512
    n_nodes, n_edges = (48, 150) if smoke else (400, 1200)
    _scenario("tc_chain", TC, tc_chain_facts(n_chain))
    _scenario("tc_rand", TC, tc_random_facts(n_nodes, n_edges))
