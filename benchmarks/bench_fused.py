"""Fused vs two-phase round execution on the transitive-closure instance —
the host-sync trajectory behind ``BENCH_tc.json``.

Runs the same deep-fixpoint TC instance (long chain + random chords, the
``bench_datalog`` layout whose recursive join hits both primary sort
columns) through the two-phase executor (``REPRO_FUSED=0``: one blocking
count pull per primitive call) and the fused executor (``REPRO_FUSED=1``:
one pull per round, and one for the whole linear tail via
``lax.while_loop``).  Reports wall time, trigger counts, rounds, derived and
final fact counts, and the host-sync totals from ``HOST_SYNC_STATS`` — the
two executors must agree on everything but the clock and the sync counts.
"""
from __future__ import annotations

import os

from benchmarks.common import emit, timed, warmup
from benchmarks.bench_datalog import TC, tc_facts
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize


def run(smoke: bool = False):
    # deep chain, few chords: many small-delta rounds — the regime where
    # per-primitive host round-trips dominate the two-phase executor (the
    # fused win shrinks on shallow, chord-heavy instances whose cost is
    # join arithmetic, not bookkeeping)
    B = tc_facts(n_chain=64 if smoke else 192, n_extra=8 if smoke else 16)
    prev = os.environ.get("REPRO_FUSED")
    try:
        for flag, tag in (("0", "two_phase"), ("1", "fused")):
            os.environ["REPRO_FUSED"] = flag
            # warm TWICE on the SAME instance: the first pass converges the
            # fused capacity planner (memoized per program fingerprint), the
            # second compiles the round/fixpoint programs at the converged
            # buckets — the timed run then measures steady state
            warmup(TC, B, modes=("tg",))
            warmup(TC, B, modes=("tg",))
            ops.SORT_STATS.reset()
            ops.HOST_SYNC_STATS.reset()
            kb = EngineKB(TC, B)
            st, t = timed(materialize, kb, mode="tg")
            emit(f"tc.{tag}", t, st.derived,
                 triggers=st.triggers, rounds=st.rounds,
                 facts=kb.num_facts(),
                 host_syncs=ops.HOST_SYNC_STATS.total(),
                 count_pulls=ops.HOST_SYNC_STATS.count_pulls,
                 fused_pulls=ops.HOST_SYNC_STATS.fused_pulls,
                 fused_retries=ops.HOST_SYNC_STATS.fused_retries)
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED", None)
        else:
            os.environ["REPRO_FUSED"] = prev


if __name__ == "__main__":
    run()
