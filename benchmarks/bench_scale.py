"""Scale trajectory — the sweep behind ``BENCH_scale.json``.

Materializes wide-TC (``tc_wide_chunks``: disjoint 4-edge chains, closure =
3.5x the base, fixpoint depth 4 regardless of size) through the fused
executor at 10^5 / 10^6 / 10^7 total facts (10^8 behind ``--huge``), on a
2x2 grid per size: store dtype (narrow int32 vs int64) x Pallas kernels
(``REPRO_USE_PALLAS`` 0/1).

Each cell runs in its own subprocess because both axes are locked at first
jax import (``JAX_ENABLE_X64`` for the int64 store; the Pallas flag is read
when the kernels first trace) and because ``ru_maxrss`` is a process
high-water mark — per-cell subprocesses give an honest peak_rss_mb per
configuration.  Inside a cell: streamed ingest via ``EngineKB.from_stream``
(timed separately as ingest throughput), one cold materialization (its
capacity-doubling recompiles are the reported ``cold_retries``), warm passes
until no planned capacity in ``plan._CAP_MEMO`` moved, then a timed
steady-state pass which must complete with ZERO overflow retries
(``warm_retries`` — the CI gate).  The timed pass also records the engine's
sort-pass counters and the roofline unit costs (bytes/flops-per-fact per op
class — sort / probe / absorb — plus the fused round and fixpoint programs,
via the trip-count-aware HLO walk in ``analysis.roofline``).

Acceptance hooks: every cell at a size must reach the exact closed-form
closure count (``tc_wide_total`` — fact parity across dtypes and kernel
paths), and at the largest size the narrow store's peak_rss_mb must come in
well under the int64 store's (the ``scale.rss_reduction.*`` rows).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["REPRO_STORE_DTYPE"] = %(dtype)r
    os.environ["REPRO_USE_PALLAS"] = %(pallas)r
    os.environ["REPRO_FUSED"] = "1"
    if %(dtype)r == "int64":
        os.environ["JAX_ENABLE_X64"] = "1"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json, time, resource
    sys.path.insert(0, %(src)r)
    import jax
    import numpy as np
    from repro.data.kb_sources import TC, tc_wide_chunks, tc_wide_total
    from repro.engine import ops, plan
    from repro.engine.materialize import EngineKB, materialize

    n_chains = %(n_chains)d
    t0 = time.perf_counter()
    kb = EngineKB.from_stream(TC, tc_wide_chunks(n_chains))
    for r in kb.rels.values():
        jax.block_until_ready(r.data)
    ingest_s = time.perf_counter() - t0
    base_rows = sum(r.count for r in kb.rels.values())
    # materialize() only rebinds kb.rels entries (buffers are immutable on
    # CPU; nothing is donated), so restoring the dict gives a fresh pass
    # without re-paying ingest
    base_rels = dict(kb.rels)

    def run_pass():
        kb.rels = dict(base_rels)
        st = materialize(kb, mode="tg")
        for r in kb.rels.values():
            jax.block_until_ready(r.data)
        return st

    # cold pass: capacity guesses double-and-recompile (reported, not gated)
    ops.HOST_SYNC_STATS.reset()
    t0 = time.perf_counter()
    st = run_pass()
    cold_s = time.perf_counter() - t0
    cold_retries = ops.HOST_SYNC_STATS.fused_retries

    # warm until the capacity memo is stable (geometric tail growth means a
    # fixed warm count is not enough)
    prev = sorted((str(k), v) for k, v in plan._CAP_MEMO.items())
    warm_passes = 0
    for _ in range(3):
        run_pass()
        warm_passes += 1
        snap = sorted((str(k), v) for k, v in plan._CAP_MEMO.items())
        if snap == prev:
            break
        prev = snap

    ops.HOST_SYNC_STATS.reset()
    ops.SORT_STATS.reset()
    t0 = time.perf_counter()
    st = run_pass()
    warm_s = time.perf_counter() - t0
    warm_retries = ops.HOST_SYNC_STATS.fused_retries
    ss = ops.SORT_STATS

    facts = sum(kb.rels[p].count for p in kb.rels if "~" not in p)
    expected = tc_wide_total(n_chains)

    from repro.analysis.roofline import (engine_fused_roofline,
                                         engine_op_roofline)
    fused_roof = engine_fused_roofline(kb, facts)
    max_rows = max(r.count for r in kb.rels.values())
    op_roof = engine_op_roofline(max_rows)

    out = {
        "n_chains": n_chains, "base_rows": base_rows,
        "facts": facts, "expected": expected,
        "parity": int(facts == expected),
        "rounds": st.rounds, "triggers": st.triggers,
        "derived": st.derived,
        "ingest_s": ingest_s,
        "ingest_rows_per_s": base_rows / max(ingest_s, 1e-9),
        "cold_s": cold_s, "cold_retries": cold_retries,
        "warm_passes": warm_passes,
        "seconds": warm_s,
        "facts_per_s": facts / max(warm_s, 1e-9),
        "warm_retries": warm_retries,
        "sort_lexsort": ss.lexsort, "sort_key": ss.key_sort,
        "sort_merges": ss.merges, "sort_skipped": ss.skipped,
        "planned_rows": int(sum(plan._CAP_MEMO.values())),
        "peak_rss_mb": round(
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0, 1),
        "roofline": {"ops": op_roof, "fused": fused_roof},
    }
    print("RESULT " + json.dumps(out))
""")

_GRID = (("int32", "0"), ("int32", "1"), ("int64", "0"), ("int64", "1"))

# tc_wide_total(W) = 14 * W at chain_len=4 (4 base edges + 10 closure facts
# per chain), so W = size // 14 hits the size to within one chain
_SIZES = ((10 ** 5, "1e5"), (10 ** 6, "1e6"), (10 ** 7, "1e7"))
_HUGE = (10 ** 8, "1e8")


def _cell(size: int, dtype: str, pallas: str) -> dict:
    script = _SCRIPT % {"dtype": dtype, "pallas": pallas, "src": _SRC,
                        "n_chains": size // 14}
    env = {k: v for k, v in os.environ.items()
           if k not in ("REPRO_STORE_DTYPE", "REPRO_USE_PALLAS",
                        "REPRO_FUSED", "REPRO_DIST", "JAX_ENABLE_X64")}
    try:
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=14400)
    except subprocess.TimeoutExpired:
        raise _CellFailed(
            f"scale cell size={size} dtype={dtype} pallas={pallas} "
            "timed out (14400 s)", reason="timeout")
    if r.returncode != 0:
        raise _CellFailed(
            f"scale cell size={size} dtype={dtype} pallas={pallas} failed:\n"
            + r.stderr[-3000:], reason="subprocess_error")
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


class _CellFailed(RuntimeError):
    """One grid cell died (timeout / OOM-kill / crash).  The sweep emits a
    failed row and keeps going: a dead interpret-mode cell at the end of a
    multi-hour sweep must not discard every completed cell before it."""

    def __init__(self, msg: str, reason: str):
        super().__init__(msg)
        self.reason = reason


def _emit_roofline(prefix: str, roof: dict) -> None:
    ops_r = roof.get("ops") or {}
    for klass in ("sort", "probe", "absorb"):
        c = ops_r.get(klass)
        if c:
            emit(f"{prefix}.roofline.{klass}", 0.0, 0,
                 flops_per_fact=round(c["flops_per_fact"], 2),
                 bytes_per_fact=round(c["bytes_per_fact"], 2),
                 peak_rss_mb=0)
    for prog, c in (roof.get("fused") or {}).items():
        emit(f"{prefix}.roofline.fused_{prog}", 0.0, 0,
             flops_per_fact=round(c["flops_per_fact"], 2),
             bytes_per_fact=round(c["bytes_per_fact"], 2),
             intensity=round(c["intensity_flops_per_byte"], 3),
             sort_ops=c["sort_ops_static"],
             peak_rss_mb=0)


def run(smoke: bool = False, huge: bool = False):
    sizes = _SIZES[:1] if smoke else _SIZES + ((_HUGE,) if huge else ())
    for size, label in sizes:
        cells = {}
        for dtype, pallas in _GRID:
            if size >= 10 ** 7 and dtype == "int64" and pallas == "1":
                # interpret-mode Pallas on an int64 store costs ~450 s per
                # pass at 10^6 (no packed keys, double-width rows) — the
                # extrapolated 10^7 cell blows the subprocess budget.  A/B
                # coverage at this size stays: pallas 0/1 via the int32
                # pair, int64-vs-narrow via the pallas=0 pair.
                emit(f"scale.tcwide{label}.{dtype}.pallas{pallas}.skipped",
                     0.0, 0, reason="interpret_mode_cell_budget",
                     peak_rss_mb=0)
                continue
            try:
                rec = _cell(size, dtype, pallas)
            except _CellFailed as e:
                print(f"FAILED {e}", file=sys.stderr)
                emit(f"scale.tcwide{label}.{dtype}.pallas{pallas}.failed",
                     0.0, 0, reason=e.reason, peak_rss_mb=0)
                continue
            cells[(dtype, pallas)] = rec
            if not rec["parity"]:
                raise RuntimeError(
                    f"fact parity broken at size={size} dtype={dtype} "
                    f"pallas={pallas}: {rec['facts']} != {rec['expected']}")
            prefix = f"scale.tcwide{label}.{dtype}.pallas{pallas}"
            emit(prefix, rec["seconds"], rec["derived"],
                 facts=rec["facts"], parity=rec["parity"],
                 facts_per_s=round(rec["facts_per_s"]),
                 ingest_rows_per_s=round(rec["ingest_rows_per_s"]),
                 cold_s=round(rec["cold_s"], 3),
                 cold_retries=rec["cold_retries"],
                 warm_retries=rec["warm_retries"],
                 warm_passes=rec["warm_passes"],
                 rounds=rec["rounds"],
                 sort_lexsort=rec["sort_lexsort"],
                 sort_key=rec["sort_key"],
                 sort_merges=rec["sort_merges"],
                 sort_skipped=rec["sort_skipped"],
                 planned_rows=rec["planned_rows"],
                 peak_rss_mb=rec["peak_rss_mb"])
            _emit_roofline(prefix, rec["roofline"])
        for pallas in ("0", "1"):
            # a grid cell may have been skipped (int64/pallas1 at >=10^7);
            # only reduce over pairs where both dtypes actually ran
            if ("int64", pallas) not in cells or ("int32", pallas) not in cells:
                continue
            wide = cells[("int64", pallas)]["peak_rss_mb"]
            narrow = cells[("int32", pallas)]["peak_rss_mb"]
            emit(f"scale.rss_reduction.{label}.pallas{pallas}", 0.0, 0,
                 rss_int64_mb=wide, rss_int32_mb=narrow,
                 reduction_pct=round(100.0 * (wide - narrow)
                                     / max(wide, 1e-9), 1),
                 peak_rss_mb=0)


if __name__ == "__main__":
    import benchmarks.common  # noqa: F401  (sys.path side effect)
    run(smoke="--smoke" in sys.argv, huge="--huge" in sys.argv)
