"""Distributed-executor scaling table — the trajectory behind
``BENCH_dist.json``.

Runs TC (deep chain + chords) and LUBM-L through the sharded shard_map
executor at ndev in {1, 2, 4, 8} (smoke: {1, 2}).  Each shard count runs in
a subprocess (``xla_force_host_platform_device_count`` is locked at first
jax init, so the parent process can't revisit it), warms once so the
capacity planner converges, then times a steady-state run.

Reported per row: wall time, derived/total facts, rounds, triggers, the
single-device ``tg`` reference fact count (``parity`` must be 1), and the
host-sync counters — ``pulls_per_round`` is the acceptance metric: ONE
blocking convergence pull per round attempt, independent of ndev.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json, time
    sys.path.insert(0, %(src)r)
    from repro.core.terms import parse_atom, parse_program
    from repro.data.kb_sources import LUBM_L, lubm_facts
    from repro.engine import ops
    from repro.engine.materialize import EngineKB, materialize

    smoke = %(smoke)r
    TC = parse_program("e(X, Y) -> T(X, Y)\\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    n_chain = 48 if smoke else 128
    B_tc = [parse_atom(f"e(v{i}, v{i+1})") for i in range(n_chain)] + \\
        [parse_atom(f"e(v{3*i+2}, v{i})") for i in range(n_chain // 8)]
    scens = [("tc", TC, B_tc),
             ("LUBM-L", LUBM_L, lubm_facts(n_univ=1 if smoke else 2))]
    out = []
    for name, P, B in scens:
        ref = EngineKB(P, B)
        materialize(ref, mode="tg")
        # warm TWICE: the first pass converges the capacity planner, the
        # second compiles every round at the converged buckets — the timed
        # run then measures steady state (same discipline as bench_fused)
        for _ in range(2):
            kb = EngineKB(P, B)
            materialize(kb, mode="tg", backend="dist")
        ops.HOST_SYNC_STATS.reset()
        kb = EngineKB(P, B)
        t0 = time.perf_counter()
        st = materialize(kb, mode="tg", backend="dist")
        t = time.perf_counter() - t0
        out.append({
            "name": name, "seconds": t, "ndev": st.extra["ndev"],
            "derived": st.derived, "facts": kb.num_facts(),
            "rounds": st.rounds, "triggers": st.triggers,
            "facts_ref": ref.num_facts(),
            "parity": int(kb.num_facts() == ref.num_facts()),
            "dist_pulls": ops.HOST_SYNC_STATS.dist_pulls,
            "dist_retries": ops.HOST_SYNC_STATS.dist_retries})
    print("RESULT " + json.dumps(out))
""")


def run(smoke: bool = False):
    scales = (1, 2) if smoke else (1, 2, 4, 8)
    for ndev in scales:
        script = _SCRIPT % {"ndev": ndev, "src": _SRC, "smoke": smoke}
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"dist bench subprocess ndev={ndev} failed:\n"
                               + r.stderr[-3000:])
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        for rec in json.loads(line[len("RESULT "):]):
            emit(f"dist.{rec['name']}.ndev{ndev}", rec["seconds"],
                 rec["derived"],
                 ndev=rec["ndev"], facts=rec["facts"],
                 facts_ref=rec["facts_ref"], parity=rec["parity"],
                 rounds=rec["rounds"], triggers=rec["triggers"],
                 dist_pulls=rec["dist_pulls"],
                 dist_retries=rec["dist_retries"],
                 pulls_per_round=round(rec["dist_pulls"]
                                       / max(rec["rounds"], 1), 3))


if __name__ == "__main__":
    import benchmarks.common  # noqa: F401  (sys.path side effect)
    run(smoke="--smoke" in sys.argv)
