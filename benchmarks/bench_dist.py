"""Distributed-executor scaling table — the trajectory behind
``BENCH_dist.json``.

Runs deep-chain TC (the O(rounds)-vs-O(phases) host-sync scenario), a wide
random-graph TC (few rounds, big per-round joins — the scenario where
sharding the sort/merge work pays off), and LUBM-L through the sharded
shard_map executor at ndev in {1, 2, 4, 8} (smoke: {1, 2}).  Each shard
count runs in a subprocess (``xla_force_host_platform_device_count`` is
locked at first jax init, so the parent process can't revisit it), warms
until the capacity planner is stable (no cap in ``plan._CAP_MEMO`` moved on
the last run — the while_loop fixpoint doubles tails geometrically, so two
fixed warm passes are not enough), then times a steady-state run.

Every subprocess also times the fused single-device executor
(``REPRO_FUSED=1``) on the same instance under the same warm discipline: it
is both the parity reference and the baseline behind ``speedup_vs_fused``
(fused seconds / dist seconds, same process so thread conditions match).
The ndev=1 subprocess additionally emits one ``dist.fused_base.*`` row per
scenario so the baseline wall time lands in the table.

Reported per dist row: wall time, derived/total facts, rounds, triggers,
parity vs fused, ``speedup_vs_fused``, and the host-sync counters —
``pulls_per_round`` is the acceptance metric (the while_loop fixpoint pulls
once per *phase exit*, so deep-chain TC must sit well under one pull per
round), with ``dist_fixpoint_pulls`` / ``dist_fixpoint_iters`` splitting
out how much of the run stayed on-device.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%(ndev)d"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json, time
    sys.path.insert(0, %(src)r)
    from repro.data.kb_sources import (TC, LUBM_L, lubm_facts,
                                       tc_chain_facts, tc_random_facts)
    from repro.engine import ops, plan
    from repro.engine.materialize import EngineKB, materialize

    smoke = %(smoke)r
    scens = [
        ("tc_chain", TC, tc_chain_facts(48 if smoke else 128)),
        ("tc_rand", TC, tc_random_facts(*((200, 600) if smoke
                                          else (500, 1500)))),
    ]
    if not smoke:  # the rule-heavy scenario: cold compiles dominate, so
        scens.append(  # it rides only the full table, not the CI smoke
            ("LUBM-L", LUBM_L, lubm_facts(n_univ=2, scale=2)))

    def steady(P, B, run, max_warm=5):
        # warm until no planned capacity moved on the last run: the timed
        # pass then hits only cached programs at converged buffer sizes
        prev = None
        for _ in range(max_warm):
            kb = EngineKB(P, B)
            run(kb)
            snap = sorted((str(k), v) for k, v in plan._CAP_MEMO.items())
            if snap == prev:
                break
            prev = snap
        ops.HOST_SYNC_STATS.reset()
        kb = EngineKB(P, B)
        t0 = time.perf_counter()
        st = run(kb)
        return time.perf_counter() - t0, st, kb

    out = []
    for name, P, B in scens:
        os.environ["REPRO_FUSED"] = "1"
        t_f, st_f, kb_f = steady(P, B, lambda kb: materialize(kb, mode="tg"))
        del os.environ["REPRO_FUSED"]
        fused = {"name": name, "seconds": t_f, "facts": kb_f.num_facts(),
                 "derived": st_f.derived, "rounds": st_f.rounds,
                 "fused_pulls": ops.HOST_SYNC_STATS.fused_pulls}
        t_d, st, kb = steady(
            P, B, lambda kb: materialize(kb, mode="tg", backend="dist"))
        s = ops.HOST_SYNC_STATS
        out.append({
            "name": name, "seconds": t_d, "ndev": st.extra["ndev"],
            "derived": st.derived, "facts": kb.num_facts(),
            "rounds": st.rounds, "triggers": st.triggers,
            "facts_ref": kb_f.num_facts(),
            "parity": int(kb.num_facts() == kb_f.num_facts()),
            "dist_pulls": s.dist_pulls, "dist_retries": s.dist_retries,
            "dist_fixpoint_pulls": s.dist_fixpoint_pulls,
            "dist_fixpoint_iters": s.dist_fixpoint_iters,
            "fused": fused})
    print("RESULT " + json.dumps(out))
""")


def run(smoke: bool = False):
    scales = (1, 2) if smoke else (1, 2, 4, 8)
    for ndev in scales:
        script = _SCRIPT % {"ndev": ndev, "src": _SRC, "smoke": smoke}
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=3600)
        if r.returncode != 0:
            raise RuntimeError(f"dist bench subprocess ndev={ndev} failed:\n"
                               + r.stderr[-3000:])
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT ")][-1]
        for rec in json.loads(line[len("RESULT "):]):
            fused = rec["fused"]
            if ndev == scales[0]:
                emit(f"dist.fused_base.{fused['name']}", fused["seconds"],
                     fused["derived"], facts=fused["facts"],
                     rounds=fused["rounds"],
                     fused_pulls=fused["fused_pulls"])
            emit(f"dist.{rec['name']}.ndev{ndev}", rec["seconds"],
                 rec["derived"],
                 ndev=rec["ndev"], facts=rec["facts"],
                 facts_ref=rec["facts_ref"], parity=rec["parity"],
                 rounds=rec["rounds"], triggers=rec["triggers"],
                 dist_pulls=rec["dist_pulls"],
                 dist_retries=rec["dist_retries"],
                 dist_fixpoint_pulls=rec["dist_fixpoint_pulls"],
                 dist_fixpoint_iters=rec["dist_fixpoint_iters"],
                 pulls_per_round=round(rec["dist_pulls"]
                                       / max(rec["rounds"], 1), 3),
                 speedup_vs_fused=round(fused["seconds"]
                                        / max(rec["seconds"], 1e-9), 3))


if __name__ == "__main__":
    import benchmarks.common  # noqa: F401  (sys.path side effect)
    run(smoke="--smoke" in sys.argv)
