"""Paper Table 2 analogue — linear scenarios.

Per scenario: chase-engine baseline (seminaive) vs TG-guided reasoning over a
precomputed instance-independent TG (tglinear + minLinear), both "w/o
cleaning" and "w/ cleaning"; plus the TG computation time (column Comp) and
TG sizes (#N, #E, D)."""
from __future__ import annotations

from benchmarks.common import emit, timed, warmup
from repro.core.tg_linear import min_linear, tglinear
from repro.data.kb_sources import LUBM_LI, linear_subset, lubm_facts, \
    rho_df_facts, RHO_DF
from repro.engine.materialize import EngineKB, materialize


def scenarios(smoke: bool = False):
    yield "LUBM-LI", LUBM_LI, lubm_facts(n_univ=1 if smoke else 4)
    if not smoke:
        yield "RHODF-LI", linear_subset(RHO_DF), rho_df_facts()


def run(smoke: bool = False):
    for name, P, B in scenarios(smoke):
        warmup(P, B[:len(B)//8] or B, modes=("seminaive",))
        # baseline: chase engine (SNE)
        kb = EngineKB(P, B)
        st, t_chase = timed(materialize, kb, mode="seminaive")
        emit(f"linear.{name}.chase", t_chase, st.derived,
             triggers=st.triggers)

        # TG computation (Comp column)
        (G, _), t_comp = timed(lambda: (min_linear(tglinear(P)), None))
        stats = G.stats()

        for cleaning, tag in ((False, "wo_clean"), (True, "w_clean")):
            kb2 = EngineKB(P, B)
            st2, t_r = timed(materialize, kb2, mode="tg_linear", tg_eg=G,
                             cleaning=cleaning)
            emit(f"linear.{name}.tg_{tag}", t_comp + t_r, st2.derived,
                 comp_us=f"{t_comp*1e6:.0f}", triggers=st2.triggers,
                 nodes=stats["nodes"], edges=stats["edges"],
                 depth=stats["depth"])


if __name__ == "__main__":
    run()
