"""Benchmark driver — one module per paper table.

Prints ``name,us_per_call,derived[,k=v...]`` CSV rows.  Each module warms the
jit caches with a small instance before timing (capacity-bucketed kernels are
compile-once-per-bucket).
"""
from __future__ import annotations

import sys

from benchmarks import (bench_chasebench, bench_datalog, bench_linear,
                        bench_rdfs, bench_scalability, bench_triggers)

TABLES = {
    "linear": bench_linear.run,          # paper Table 2
    "datalog": bench_datalog.run,        # paper Table 3
    "chasebench": bench_chasebench.run,  # paper Table 4
    "triggers": bench_triggers.run,      # paper Table 5 / 8a
    "rdfs": bench_rdfs.run,              # paper Table 6
    "scalability": bench_scalability.run,  # paper Table 7
}


def main() -> None:
    which = sys.argv[1:] or list(TABLES)
    print("name,us_per_call,derived,extra...")
    for name in which:
        TABLES[name]()


if __name__ == "__main__":
    main()
