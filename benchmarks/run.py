"""Benchmark driver — one module per paper table.

Prints ``name,us_per_call,derived[,k=v...]`` CSV rows.  Each module warms the
jit caches with a small instance before timing (capacity-bucketed kernels are
compile-once-per-bucket).

``--smoke`` runs every table on tiny instances (seconds, not minutes) and
writes the rows to ``BENCH_smoke.json`` — the machine-readable perf
trajectory CI uploads as an artifact on every push.  ``--out FILE`` overrides
the JSON path (also usable without ``--smoke`` for full runs).

Whenever the ``tc`` table runs (it is part of the default set), its fused vs
two-phase rows are additionally written to ``BENCH_tc.json`` at the repo
root — the stable per-commit trajectory of the transitive-closure
benchmark: trigger counts, rounds, wall time, and host-sync counts for both
executors.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys

from benchmarks import (bench_chasebench, bench_datalog, bench_delta,
                        bench_dist, bench_fused, bench_linear, bench_rdfs,
                        bench_recovery, bench_scalability, bench_scale,
                        bench_triggers)
from benchmarks import common

TABLES = {
    "linear": bench_linear.run,          # paper Table 2
    "datalog": bench_datalog.run,        # paper Table 3
    "chasebench": bench_chasebench.run,  # paper Table 4
    "triggers": bench_triggers.run,      # paper Table 5 / 8a
    "rdfs": bench_rdfs.run,              # paper Table 6
    "scalability": bench_scalability.run,  # paper Table 7
    "tc": bench_fused.run,               # fused vs two-phase host syncs
    "dist": bench_dist.run,              # sharded executor scaling (ndev)
    "delta": bench_delta.run,            # incremental maintenance cost
    "scale": bench_scale.run,            # 10^5..10^8 dtype/pallas sweep
    "recovery": bench_recovery.run,      # checkpoint overhead + resume cost
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("tables", nargs="*", choices=[[], *TABLES],
                    help="subset of tables (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny instances; write BENCH_smoke.json")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default BENCH_smoke.json "
                         "with --smoke, none otherwise)")
    ap.add_argument("--huge", action="store_true",
                    help="extend the scale sweep to 10^8 facts")
    args = ap.parse_args()

    which = args.tables or list(TABLES)
    common.reset_results()
    print("name,us_per_call,derived,extra...")
    for name in which:
        if name == "scale":
            TABLES[name](smoke=args.smoke, huge=args.huge)
        else:
            TABLES[name](smoke=args.smoke)

    def write_payload(path, rows, **extra):
        payload = {
            "mode": "smoke" if args.smoke else "full",
            "python": platform.python_version(),
            "use_pallas": os.environ.get("REPRO_USE_PALLAS", "0"),
            **extra,
            "results": rows,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"[bench] wrote {len(rows)} rows to {path}", file=sys.stderr)

    out = args.out or ("BENCH_smoke.json" if args.smoke else None)
    if out:
        write_payload(out, common.RESULTS, tables=which)
    if "tc" in which:
        # smoke runs write a separate file so they never clobber the
        # committed full-run trajectory at BENCH_tc.json
        write_payload("BENCH_tc_smoke.json" if args.smoke
                      else "BENCH_tc.json",
                      [r for r in common.RESULTS
                       if r["name"].startswith("tc.")])
    if "dist" in which:
        # same convention for the distributed-executor scaling trajectory
        write_payload("BENCH_dist_smoke.json" if args.smoke
                      else "BENCH_dist.json",
                      [r for r in common.RESULTS
                       if r["name"].startswith("dist.")])
    if "delta" in which:
        # and for the incremental-maintenance cost trajectory
        write_payload("BENCH_delta_smoke.json" if args.smoke
                      else "BENCH_delta.json",
                      [r for r in common.RESULTS
                       if r["name"].startswith("delta.")])
    if "recovery" in which:
        # and for the checkpoint-overhead / resume-cost trajectory
        write_payload("BENCH_recovery_smoke.json" if args.smoke
                      else "BENCH_recovery.json",
                      [r for r in common.RESULTS
                       if r["name"].startswith("recovery.")])
    if "scale" in which:
        # and for the 10^5..10^8 dtype/pallas scale trajectory
        write_payload("BENCH_scale_smoke.json" if args.smoke
                      else "BENCH_scale.json",
                      [r for r in common.RESULTS
                       if r["name"].startswith("scale.")],
                      huge=args.huge)


if __name__ == "__main__":
    main()
