"""Paper Table 7 analogue — scalability sweep: LUBM-L at growing scale;
reports runtime, derived facts and throughput (facts/s).  The paper scales to
17B facts on 256 GB; this container is 1-core CPU so the sweep is truncated,
with per-scale throughput showing the near-linear trend."""
from __future__ import annotations

import os

from benchmarks.common import emit, timed, warmup
from repro.data.kb_sources import LUBM_L, lubm_facts
from repro.engine.materialize import EngineKB, materialize


def run(smoke: bool = False):
    scales = (1, 2, 4, 8)
    if smoke:
        scales = (1, 2)
    elif os.environ.get("BENCH_LARGE"):
        scales = (1, 2, 4, 8, 16, 32)
    warmup(LUBM_L, lubm_facts(n_univ=1), modes=("tg",))
    for n_univ in scales:
        B = lubm_facts(n_univ=n_univ)
        kb = EngineKB(LUBM_L, B)
        st, t = timed(materialize, kb, mode="tg")
        total = kb.num_facts()
        # numbers, not preformatted strings: BENCH_*.json consumers plot
        # these fields directly
        # memory lands in the uniform peak_rss_mb column emit() adds
        emit(f"scalability.LUBM-L.univ{n_univ}", t, st.derived,
             base=len(B), total=total,
             facts_per_s=round(st.derived / max(t, 1e-9)))


if __name__ == "__main__":
    run()
