"""Paper Table 4 analogue — ChaseBench-style recursive existential scenario
(iBench STB/ONT shape): non-linear rules, existentials, heavy joins."""
from __future__ import annotations

from benchmarks.common import emit, timed, warmup
from repro.data.kb_sources import CHASEBENCH, chasebench_facts
from repro.engine.materialize import EngineKB, materialize


def run(smoke: bool = False):
    B = chasebench_facts(n=60 if smoke else 400)
    warmup(CHASEBENCH, chasebench_facts(n=60), modes=("seminaive", "tg"), max_rounds=40)
    for mode in ("seminaive", "tg"):
        kb = EngineKB(CHASEBENCH, B)
        st, t = timed(materialize, kb, mode=mode, max_rounds=40)
        emit(f"chasebench.STB-like.{mode}", t, st.derived,
             triggers=st.triggers, rounds=st.rounds)


if __name__ == "__main__":
    run()
