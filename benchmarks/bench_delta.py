"""Incremental-maintenance cost table — the trajectory behind
``BENCH_delta.json``.

The claim under test: once a KB is materialized, maintaining it under a
small update batch must cost ~|affected delta|, not ~|KB| — the
``materialize_delta`` call re-fires only rules touched by the delta
(fused executor, warm capacity plans), so a one-fact insert into a
100k-fact closure is orders of magnitude cheaper than re-materializing.

Per scenario (deep-chain TC and wide random-graph TC, same instances as
the dist table):

* ``delta.<scen>.scratch`` — steady-state from-scratch fused
  materialization (warmed until ``plan._CAP_MEMO`` is stable), the
  baseline every delta row is normalized against (``frac_of_scratch``).
* ``delta.<scen>.insert.dN`` / ``delete.dN`` — a batch of N disconnected
  fresh edges inserted (each derives one closure fact) then DRed-deleted
  back, N in {1, 8, 64}: the cost should grow with N, not with |KB|.
* ``delta.tc_chain.cascade1`` — one edge PREPENDED to the chain, whose
  closure cascades one hop per round (O(chain) rounds, O(chain) facts):
  the deep-cascade case where propagation hands off to the fused
  ``lax.while_loop`` fixpoint.  Delta cost tracks the DERIVED delta, not
  the batch size, and still undercuts from-scratch.

Each delta row reports wall seconds, ``frac_of_scratch``, the DRed/insert
counters (``over_deleted`` / ``rescued`` / ``propagated``), and
``retries`` — fused capacity-overflow retries during the timed calls,
which must be 0: the batches are sized within the warm plans, so a
nonzero count means ``_CAP_MEMO`` reuse across delta calls regressed.
``delta.<scen>.insert.d1`` is the CI smoke gate (small-delta cost below
half of from-scratch wall)."""
from __future__ import annotations

import os
import time

from benchmarks.common import emit
from repro.core.terms import Atom
from repro.data.kb_sources import TC, tc_chain_facts, tc_random_facts
from repro.engine import ops, plan
from repro.engine.materialize import EngineKB, materialize


def _steady_scratch(P, B, max_warm=5):
    """Warm until no planned capacity moved on the last run, then time a
    steady-state fused from-scratch materialization."""
    prev = None
    for _ in range(max_warm):
        kb = EngineKB(P, B)
        materialize(kb, mode="tg")
        snap = sorted((str(k), v) for k, v in plan._CAP_MEMO.items())
        if snap == prev:
            break
        prev = snap
    kb = EngineKB(P, B)
    t0 = time.perf_counter()
    st = materialize(kb, mode="tg")
    return time.perf_counter() - t0, st, kb


def _edge_batch(tag, n):
    """n disconnected fresh edges: each derives exactly one closure fact."""
    return [Atom("e", (f"{tag}x{i}", f"{tag}y{i}")) for i in range(n)]


def run(smoke: bool = False):
    prior = os.environ.get("REPRO_FUSED")
    os.environ["REPRO_FUSED"] = "1"
    try:
        chain_n = 48 if smoke else 128
        scens = [
            ("tc_chain", TC, tc_chain_facts(chain_n)),
            ("tc_rand", TC, tc_random_facts(*((200, 600) if smoke
                                              else (400, 1200)))),
        ]
        sizes = (1, 8) if smoke else (1, 8, 64)
        for name, P, B in scens:
            scratch_s, st0, kb0 = _steady_scratch(P, B)
            emit(f"delta.{name}.scratch", scratch_s, st0.derived,
                 facts=kb0.num_facts(), rounds=st0.rounds)

            kb = EngineKB(P, B)
            materialize(kb, mode="tg")
            for n in sizes:
                # warm the delta paths at THIS batch size (delta capacity
                # buckets are pow2(|batch|), so each size compiles its own
                # programs) on a throwaway cycle, then time fresh batches
                wb = _edge_batch(f"w{n}", n)
                kb.materialize_delta(insertions=wb)
                kb.materialize_delta(deletions=wb)
                batch = _edge_batch(f"b{n}", n)
                r0 = ops.HOST_SYNC_STATS.fused_retries
                t0 = time.perf_counter()
                st = kb.materialize_delta(insertions=batch)
                t_ins = time.perf_counter() - t0
                emit(f"delta.{name}.insert.d{n}", t_ins,
                     st.extra["propagated"],
                     frac_of_scratch=round(t_ins / scratch_s, 4),
                     retries=ops.HOST_SYNC_STATS.fused_retries - r0,
                     rounds=st.rounds, facts=kb.num_facts())
                t0 = time.perf_counter()
                st = kb.materialize_delta(deletions=batch)
                t_del = time.perf_counter() - t0
                emit(f"delta.{name}.delete.d{n}", t_del,
                     st.extra["over_deleted"],
                     frac_of_scratch=round(t_del / scratch_s, 4),
                     retries=ops.HOST_SYNC_STATS.fused_retries - r0,
                     over_deleted=st.extra["over_deleted"],
                     rescued=st.extra["rescued"], facts=kb.num_facts())
            assert kb.num_facts() == kb0.num_facts(), \
                "delta cycles did not restore the from-scratch store"

        # one edge PREPENDED to the chain: the closure cascades one hop per
        # round (O(chain) rounds), the case the fused while_loop handoff
        # exists for — cost tracks the derived delta, not the KB
        P, B = TC, tc_chain_facts(chain_n)
        kb = EngineKB(P, B)
        materialize(kb, mode="tg")
        for tag in ("wp", "bp"):                     # warm cycle, timed cycle
            head = [Atom("e", (f"{tag}0", "v0"))]
            t0 = time.perf_counter()
            st = kb.materialize_delta(insertions=head)
            t_ext = time.perf_counter() - t0
            kb.materialize_delta(deletions=head)
        emit("delta.tc_chain.cascade1", t_ext, st.extra["propagated"],
             rounds=st.rounds, fused=int(bool(st.extra.get("fused"))),
             facts=kb.num_facts())
    finally:
        if prior is None:
            os.environ.pop("REPRO_FUSED", None)
        else:
            os.environ["REPRO_FUSED"] = prior
