"""Paper Table 6 analogue — ρDF (RDFS subset) scenario: taxonomy closure,
subproperty closure, domain/range typing (WebPIE/Inferray comparison shape)."""
from __future__ import annotations

from benchmarks.common import emit, timed, warmup
from repro.data.kb_sources import RHO_DF, rho_df_facts
from repro.engine.materialize import EngineKB, materialize


def run(smoke: bool = False):
    if smoke:
        B = rho_df_facts(n_classes=12, n_props=6, n_instances=120)
    else:
        B = rho_df_facts(n_classes=60, n_props=20, n_instances=1500)
    warmup(RHO_DF, rho_df_facts(n_instances=150))
    for mode in ("seminaive", "tg_noopt", "tg"):
        kb = EngineKB(RHO_DF, B)
        st, t = timed(materialize, kb, mode=mode)
        emit(f"rdfs.rhodf.{mode}", t, st.derived, triggers=st.triggers,
             rounds=st.rounds)


if __name__ == "__main__":
    run()
