"""Paper Table 3 analogue — Datalog scenarios (LUBM-L / LUBM-LE) plus a
transitive-closure instance that isolates the sorted-store engine win.

LUBM columns: chase baseline (seminaive/VLog-like per-rule filtering),
TG-guided without optimizations (round-level filtering only), and TG-guided
m+r (Def. 23 antijoin restriction).

The TC rows run the same instance twice — with the sortedness invariant
honored (``REPRO_SORTED_STORE=1``, the default: antijoin probes the sorted
store, unions are incremental merges) and with it disabled (seed behavior:
every antijoin/dedup re-lexsorts) — and report the engine sort-pass counts
(``sorts``/``skipped``/``merges``) alongside wall time."""
from __future__ import annotations

import os

from benchmarks.common import emit, timed, warmup
from repro.core.terms import parse_atom, parse_program
from repro.data.kb_sources import LUBM_L, LUBM_LE, lubm_facts
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize

# TC with the closure relation laid out as T(to, from): the recursive join is
# then on column 0 of BOTH the delta and the edge store — i.e. on their
# primary sort column — so the sorted-store engine runs the whole fixpoint
# without re-sorting either join input (the index-orientation choice a
# sorted store rewards; the resort baseline re-sorts both sides every round).
TC = parse_program("""
    e(X, Y) -> T(Y, X)
    T(Y, X) & e(Y, Z) -> T(Z, X)
""")


def tc_facts(n_chain: int = 96, n_extra: int = 64, seed: int = 0):
    """A long path (deep fixpoint, many rounds) plus random chords."""
    import numpy as np
    rng = np.random.default_rng(seed)
    edges = [(i, i + 1) for i in range(n_chain)]
    edges += [tuple(e) for e in rng.integers(0, n_chain, (n_extra, 2))]
    return [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


def run_tc(smoke: bool = False):
    B = tc_facts(n_chain=24 if smoke else 96, n_extra=16 if smoke else 64)
    prev = os.environ.get("REPRO_SORTED_STORE")
    try:
        for flag, tag in (("1", "sorted_store"), ("0", "resort_baseline")):
            os.environ["REPRO_SORTED_STORE"] = flag
            # warm the jit caches on the SAME instance (capacity-bucketed
            # kernels compile per bucket; timing measures steady state)
            warmup(TC, B, modes=("tg",))
            ops.SORT_STATS.reset()
            kb = EngineKB(TC, B)
            st, t = timed(materialize, kb, mode="tg")
            emit(f"datalog.TC.tg_{tag}", t, st.derived,
                 triggers=st.triggers, rounds=st.rounds,
                 sorts=ops.SORT_STATS.total_sorts(),
                 skipped=ops.SORT_STATS.skipped,
                 merges=ops.SORT_STATS.merges)
    finally:
        if prev is None:
            os.environ.pop("REPRO_SORTED_STORE", None)
        else:
            os.environ["REPRO_SORTED_STORE"] = prev


def run(smoke: bool = False):
    n_univ = 1 if smoke else 4
    scenarios = (("LUBM-L", LUBM_L),) if smoke else (("LUBM-L", LUBM_L),
                                                     ("LUBM-LE", LUBM_LE))
    for name, P in scenarios:
        B = lubm_facts(n_univ=n_univ)
        warmup(P, lubm_facts(n_univ=1))
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="seminaive")
        emit(f"datalog.{name}.chase", t, st.derived, triggers=st.triggers,
             rounds=st.rounds)

        # TG no-opt: round filtering, no Def. 23 prefilter
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="tg_noopt")
        emit(f"datalog.{name}.tg_noopt", t, st.derived, triggers=st.triggers,
             rounds=st.rounds)

        # TG m+r
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="tg")
        emit(f"datalog.{name}.tg_m_r", t, st.derived, triggers=st.triggers,
             rounds=st.rounds)

    run_tc(smoke)


if __name__ == "__main__":
    run()
