"""Paper Table 3 analogue — Datalog scenarios (LUBM-L / LUBM-LE).

Columns: chase baseline (seminaive/VLog-like per-rule filtering), TG-guided
without optimizations (round-level filtering only), and TG-guided m+r
(Def. 23 antijoin restriction)."""
from __future__ import annotations

from benchmarks.common import emit, peak_rss_mb, timed, warmup
from repro.data.kb_sources import LUBM_L, LUBM_LE, lubm_facts
from repro.engine.materialize import EngineKB, materialize


def run():
    for name, P in (("LUBM-L", LUBM_L), ("LUBM-LE", LUBM_LE)):
        B = lubm_facts(n_univ=4)
        warmup(P, lubm_facts(n_univ=1))
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="seminaive")
        emit(f"datalog.{name}.chase", t, st.derived, triggers=st.triggers,
             rounds=st.rounds, mem_mb=f"{peak_rss_mb():.0f}")

        # TG no-opt: round filtering, no Def. 23 prefilter
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="tg_noopt")
        emit(f"datalog.{name}.tg_noopt", t, st.derived, triggers=st.triggers,
             rounds=st.rounds, mem_mb=f"{peak_rss_mb():.0f}")

        # TG m+r
        kb = EngineKB(P, B)
        st, t = timed(materialize, kb, mode="tg")
        emit(f"datalog.{name}.tg_m_r", t, st.derived, triggers=st.triggers,
             rounds=st.rounds, mem_mb=f"{peak_rss_mb():.0f}")


if __name__ == "__main__":
    run()
