"""Paper Table 5/8a analogue — the hardware-independent #trigger metric:
chase (SNE) vs TG-guided (no-opt) vs TG m+r across scenarios, plus the
symbolic-layer cross-check on a reduced instance."""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core.chase import chase
from repro.core.tg_datalog import tgmat
from repro.data.kb_sources import (LUBM_L, LUBM_LE, RHO_DF, lubm_facts,
                                   rho_df_facts)
from repro.engine.materialize import EngineKB, materialize


def run(smoke: bool = False):
    scenarios = [
        ("LUBM-L", LUBM_L, lubm_facts(n_univ=1 if smoke else 3)),
        ("LUBM-LE", LUBM_LE, lubm_facts(n_univ=1 if smoke else 2)),
        ("RHODF", RHO_DF, rho_df_facts(n_instances=60 if smoke else 400)),
    ]
    for name, P, B in scenarios:
        row = {}
        for mode in ("seminaive", "tg_noopt", "tg"):
            kb = EngineKB(P, B)
            st, t = timed(materialize, kb, mode=mode)
            row[mode] = st.triggers
            emit(f"triggers.{name}.{mode}", t, st.derived,
                 triggers=st.triggers)
        assert row["tg"] <= row["tg_noopt"], row

    # symbolic cross-check (reduced): TGmat trigger count vs chase
    P, B = LUBM_L, lubm_facts(n_univ=1)
    ch, t_ch = timed(chase, P, B)
    (I, eg, st), t_tg = timed(tgmat, P, B)
    emit("triggers.symbolic.chase", t_ch, ch.derived, triggers=ch.triggers)
    emit("triggers.symbolic.tgmat", t_tg, st["derived"],
         triggers=st["triggers"], nodes=st["nodes"], depth=st["depth"])


if __name__ == "__main__":
    run()
