"""Narrow-dtype store: executor parity across dtypes, key-packing parity
vs numpy oracles, ingest-time overflow contracts, and the streamed
(chunked-ndarray) ingest path."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.terms import parse_atom, parse_program
from repro.engine import ops
from repro.engine.dictionary import Dictionary
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import Relation, id_range, pad_value, store_dtype

TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def _chain(n, extra=0, seed=0):
    rng = np.random.default_rng(seed)
    edges = [(i, i + 1) for i in range(n)]
    edges += [tuple(e) for e in rng.integers(0, n, (extra, 2))]
    return [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


# ---------------------------------------------------------------------------
# engine parity: int16 == int32 closures across executors / kernel paths
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ["0", "1"])
@pytest.mark.parametrize("pallas", ["0", "1"])
def test_int16_matches_int32_closure(fused, pallas, monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", fused)
    monkeypatch.setenv("REPRO_USE_PALLAS", pallas)
    B = _chain(20, extra=12, seed=5)
    kb32 = EngineKB(TC, B, dtype=np.int32)
    materialize(kb32, mode="tg")
    kb16 = EngineKB(TC, B, dtype=np.int16)
    materialize(kb16, mode="tg")
    assert kb16.rels["T"].dtype == np.dtype(np.int16)
    assert kb32.rels["T"].dtype == np.dtype(np.int32)
    assert kb16.decode_facts() == kb32.decode_facts()


def test_store_dtype_env(monkeypatch):
    monkeypatch.setenv("REPRO_STORE_DTYPE", "int16")
    assert store_dtype() == np.dtype(np.int16)
    kb = EngineKB(TC, _chain(4))
    assert kb.rels["e"].dtype == np.dtype(np.int16)
    monkeypatch.setenv("REPRO_STORE_DTYPE", "int64")
    # int64 stores need an x64-enabled process (see subprocess test below)
    with pytest.raises(RuntimeError):
        store_dtype()


# ---------------------------------------------------------------------------
# packing parity vs numpy oracles
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [np.int16, np.int32])
def test_lexsort_core_matches_np_lexsort(dtype):
    rng = np.random.default_rng(7)
    hi = min(200, id_range(np.dtype(dtype))[1])
    rows = rng.integers(0, hi, (100, 2)).astype(dtype)
    got = np.asarray(ops.lexsort_core(rows))
    ref = rows[np.lexsort(rows.T[::-1])]
    assert got.dtype == rows.dtype
    assert (got == ref).all()


@pytest.mark.parametrize("dtype", [np.int16, np.int32])
def test_pack_rows2_roundtrip_order(dtype):
    """The packed double-width key must sort identically to row-major
    lexicographic order, including values adjacent to the PAD sentinel."""
    dt = np.dtype(dtype)
    hi = id_range(dt)[1]
    rows = np.array([[0, 0], [0, hi], [hi, 0], [hi, hi], [1, hi - 1]], dt)
    import jax.numpy as jnp
    keys = np.asarray(ops.pack_rows2(jnp.asarray(rows)))
    np_order = np.lexsort(rows.T[::-1])
    key_order = np.argsort(keys, kind="stable")
    assert (np_order == key_order).all()


def test_member_mask_pack_vs_binary_search_fallback():
    """int32 rows take the packed-key path; the same query through the
    per-column binary-search core (the int64/wide fallback) must agree."""
    rng = np.random.default_rng(11)
    hay = np.unique(rng.integers(0, 60, (80, 2)).astype(np.int32), axis=0)
    probe = rng.integers(0, 60, (40, 2)).astype(np.int32)
    import jax.numpy as jnp
    hay_j, probe_j = jnp.asarray(hay), jnp.asarray(probe)
    packed = np.asarray(ops.member_mask_core(probe_j, hay_j))
    lo, hi = ops.lex_range_core(hay_j, probe_j)
    fallback = np.asarray(lo < hi)
    ref = np.array([tuple(r) in {tuple(h) for h in hay} for r in probe])
    assert (packed.astype(bool) == ref).all()
    assert (fallback == ref).all()


@pytest.mark.parametrize("dtype", [np.int16, np.int32])
@pytest.mark.parametrize("n", [0, 1, 3, 7])
def test_dedup_edge_shapes(dtype, n):
    """Empty and non-pow2 row counts through the dtype-generic cores."""
    rng = np.random.default_rng(n)
    hi = min(40, id_range(np.dtype(dtype))[1])
    rows = rng.integers(0, hi, (n, 2)).astype(dtype)
    rel = Relation.from_numpy(rows)
    out = ops.dedup(rel)
    assert out.dtype == np.dtype(dtype)
    assert out.rows_set() == {tuple(r) for r in rows.tolist()}


# ---------------------------------------------------------------------------
# overflow contracts: fail at ingest, never wrap
# ---------------------------------------------------------------------------
def test_relation_narrowing_overflow():
    rows = np.array([[70000, 1]], np.int64)
    with pytest.raises(OverflowError):
        Relation.from_numpy(rows, dtype=np.int16)
    # PAD itself is reserved even when in range
    pad = int(pad_value(np.dtype(np.int16)))
    with pytest.raises(OverflowError):
        Relation.from_numpy(np.array([[pad, 0]], np.int64), dtype=np.int16)


def test_dictionary_overflow_is_atomic():
    d = Dictionary(np.int16)
    with pytest.raises(OverflowError):
        d.encode_columns(np.arange(80000, dtype=np.int64).reshape(-1, 2))
    assert len(d) == 0
    with pytest.raises(OverflowError):
        for i in range(40000):
            d.encode(f"t{i}")


def test_skolem_overflow_int16():
    d = Dictionary(np.int16)
    lo = id_range(np.dtype(np.int16))[0]
    with pytest.raises(OverflowError):
        for i in range(-lo + 1):
            d.skolem(("r", "x", (i,)))


# ---------------------------------------------------------------------------
# int64 store: requires an x64-enabled process end to end
# ---------------------------------------------------------------------------
def test_int64_store_subprocess_parity():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    script = textwrap.dedent(f"""
        import os
        os.environ["JAX_ENABLE_X64"] = "1"
        os.environ["REPRO_STORE_DTYPE"] = "int64"
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        import sys
        sys.path.insert(0, {src!r})
        import numpy as np
        from repro.core.terms import parse_atom, parse_program
        from repro.engine.materialize import EngineKB, materialize
        P = parse_program('e(X,Y) -> T(X,Y)\\nT(X,Y) & e(Y,Z) -> T(X,Z)')
        B = [parse_atom(f'e(v{{i}}, v{{i+1}})') for i in range(12)]
        for fused in ("0", "1"):
            os.environ["REPRO_FUSED"] = fused
            kb = EngineKB(P, B)
            materialize(kb, mode="tg")
            assert kb.rels["T"].dtype == np.dtype(np.int64), kb.rels["T"].dtype
            assert kb.rels["T"].count == 12 * 13 // 2, kb.rels["T"].count
        print("OK64")
    """)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK64" in r.stdout


# ---------------------------------------------------------------------------
# streamed ingest
# ---------------------------------------------------------------------------
def test_from_stream_matches_atom_ingest():
    from repro.core.terms import Atom
    rng = np.random.default_rng(3)
    edges = rng.integers(0, 30, (200, 2)).astype(np.int32)
    atoms = [Atom("e", (a, b)) for a, b in edges.tolist()]
    kb_atoms = EngineKB(TC, atoms)
    materialize(kb_atoms, mode="tg")
    # overlapping chunks: ingest must dedup against the store
    chunks = [("e", edges[:120]), ("e", edges[80:])]
    kb_stream = EngineKB.from_stream(TC, iter(chunks))
    materialize(kb_stream, mode="tg")
    assert kb_stream.decode_facts() == kb_atoms.decode_facts()


def test_from_arrays_dict_form():
    kb = EngineKB.from_arrays(
        TC, {"e": np.array([[0, 1], [1, 2]], np.int32)})
    materialize(kb, mode="tg")
    assert kb.rels["T"].count == 3


def test_tc_wide_chunks_closure_count():
    from repro.data.kb_sources import tc_wide_chunks, tc_wide_total
    kb = EngineKB.from_stream(TC, tc_wide_chunks(7, chunk_rows=8))
    materialize(kb, mode="tg")
    total = sum(kb.rels[p].count for p in kb.rels if "~" not in p)
    assert total == tc_wide_total(7) == 7 * 14


def test_tc_wide_chunks_overflow():
    from repro.data.kb_sources import tc_wide_chunks
    with pytest.raises(OverflowError):
        next(tc_wide_chunks(50000, dtype=np.int16))


def test_tc_random_facts_uses_store_dtype(monkeypatch):
    from repro.data import kb_sources
    monkeypatch.setenv("REPRO_STORE_DTYPE", "int16")
    facts = kb_sources.tc_random_facts(n_nodes=50, n_edges=100)
    assert all(a.pred == "e" for a in facts)


# ---------------------------------------------------------------------------
# dictionary round-trip property
# ---------------------------------------------------------------------------
def test_encode_columns_roundtrip_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    term = st.one_of(st.integers(-2 ** 40, 2 ** 40),
                     st.text(max_size=6))

    @settings(deadline=None, max_examples=50)
    @given(st.lists(st.tuples(term, term), max_size=40), st.data())
    def check(pairs, data):
        d = Dictionary(np.int32)
        # split the batch at an arbitrary point: interning must be stable
        # across successive batches
        cut = data.draw(st.integers(0, len(pairs)))
        outs = []
        for part in (pairs[:cut], pairs[cut:]):
            if not part:
                continue
            arr = np.array(part, dtype=object)
            outs.append((part, d.encode_columns(arr)))
        for part, ids in outs:
            assert ids.dtype == np.dtype(np.int32)
            for (a, b), (ia, ib) in zip(part, ids.tolist()):
                assert d.decode(ia) == a and d.decode(ib) == b
                assert d.encode(a) == ia and d.encode(b) == ib

    check()


def test_encode_many_matches_encode():
    d1, d2 = Dictionary(np.int32), Dictionary(np.int32)
    terms = [f"s{i % 9}" for i in range(100)] + list(range(50)) * 2
    assert d1.encode_many(terms) == [d2.encode(t) for t in terms]


def test_encode_many_tuple_terms():
    # tuples are hashable terms; the bulk path must intern each tuple as
    # ONE term, not splat its elements into separate ids
    d = Dictionary(np.int32)
    terms = [(i % 7, i % 5) for i in range(70)]
    ids = d.encode_many(terms)
    assert len(ids) == len(terms)
    assert [d.decode(i) for i in ids] == terms
    assert d.encode_many(terms) == ids          # stable re-intern
    assert d.encode(terms[3]) == ids[3]          # scalar path agrees


def test_encode_many_ragged_tuples_fall_back():
    # unequal-length tuples are unorderable for np.unique; the bulk path
    # must fall back per-term instead of raising
    d = Dictionary(np.int32)
    terms = [(1, 2), (1, 2, 3)] * 40
    ids = d.encode_many(terms)
    assert [d.decode(i) for i in ids] == terms


def test_encode_columns_uint64_no_wrap():
    # a native uint64 ndarray above int64 max must not astype-wrap into a
    # negative (null-colliding) term; it routes to the generic store
    d = Dictionary(np.int32)
    big = int(np.iinfo(np.uint64).max)
    col = np.array([big, 5, 7], dtype=np.uint64).reshape(-1, 1)
    ids = d.encode_columns(col)
    assert [d.decode(int(i)) for i in ids[:, 0]] == [big, 5, 7]
    assert d.encode(big) == int(ids[0, 0])
    # in-range unsigned input still takes the vectorized int path
    d2 = Dictionary(np.int32)
    ok = np.arange(100, dtype=np.uint64).reshape(-1, 2)
    assert [d2.decode(int(i))
            for i in d2.encode_columns(ok).reshape(-1)] == list(range(100))
