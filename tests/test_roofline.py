"""HLO cost analyzer: trip-count awareness and collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo_analysis as HA
from repro.analysis import roofline as RL


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_trip_count_multiplies_flops():
    n, L = 128, 8

    def scanned(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=L)
        return h

    def single(x, w):
        return jnp.tanh(x @ w)

    s = jax.ShapeDtypeStruct((n, n), jnp.float32)
    t_scan = HA.analyze_text(_compile(scanned, s, s).as_text())
    t_one = HA.analyze_text(_compile(single, s, s).as_text())
    ratio = t_scan["flops"] / t_one["flops"]
    assert 0.9 * L < ratio < 1.1 * L, ratio


def test_dot_flops_exact():
    m, k, n = 64, 32, 16

    def f(a, b):
        return a @ b

    c = _compile(f, jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    t = HA.analyze_text(c.as_text())
    assert abs(t["flops"] - 2 * m * k * n) / (2 * m * k * n) < 0.05


def test_nested_scan_multiplies():
    def f(x):
        def outer(h, _):
            def inner(g, _):
                return g * 1.5 + 1.0, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h

    c = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32))
    t = HA.analyze_text(c.as_text())
    # 12 executions of the elementwise body on 64 lanes (>= 64*12 flops-ish)
    assert t["flops"] >= 64 * 12


def test_roofline_terms():
    class FakeCost(dict):
        pass
    hlo = ""
    rr = RL.analyze("a", "s", "16x16", 256, {"flops": 1e12}, hlo, 6e15)
    assert rr.chips == 256
    assert rr.bottleneck in ("compute", "memory", "collective")


def test_collective_parse():
    txt = """
ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %p = f32[16,16]{1,0} parameter(0)
  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
}
"""
    t = HA.analyze_text(txt)
    assert t["coll"]["all-reduce"] == 2 * 16 * 16 * 4   # 2x ring factor
