"""Unit tests: terms, parser, normalization, unification, containment."""
import pytest

from repro.core.terms import (Atom, Null, Program, Rule, Var, example1_program,
                              parse_atom, parse_program, parse_rule)
from repro.core.unify import (Index, cq_contained, entails, equivalent,
                              exists_hom, homomorphisms, instance_hom, mgu)


def test_parse_atom():
    a = parse_atom("r(X, c1)")
    assert a.pred == "r" and a.args == (Var("X"), "c1")


def test_parse_rule_existential():
    r = parse_rule("r(X, Y) -> exists Z. T(Y, X, Z)")
    assert r.existentials == [Var("Z")]
    assert r.frontier == [Var("Y"), Var("X")]
    assert not r.is_datalog and r.is_linear


def test_program_edb_idb():
    P = example1_program()
    assert P.edb == {"r"} and P.idb == {"R", "T"}
    assert P.is_linear and not P.is_datalog


def test_normalize_mixed_bodies():
    P = parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)
    Pn = P.normalize()
    # the mixed body rule must now reference the aux IDB twin of e
    preds = {a.pred for r in Pn for a in r.body}
    assert "e~aux" in preds
    assert all(
        {a.pred for a in r.body} <= Pn.edb
        or {a.pred for a in r.body} <= Pn.idb
        for r in Pn)


def test_homomorphisms_basic():
    facts = [parse_atom("p(a, b)"), parse_atom("p(b, c)")]
    homs = homomorphisms([parse_atom("p(X, Y)"), parse_atom("p(Y, Z)")], facts)
    assert len(homs) == 1
    assert homs[0][Var("X")] == "a" and homs[0][Var("Z")] == "c"


def test_instance_hom_nulls():
    I1 = [Atom("p", ("a", Null(1)))]
    I2 = [Atom("p", ("a", "b"))]
    assert entails(I2, I1)          # null maps to b
    assert not entails(I1, I2)      # constant b cannot map to null
    assert not equivalent(I1, I2)


def test_cq_containment():
    # Q1(X) <- p(X, Y) & p(Y, X)   ⊆   Q2(X) <- p(X, Y)
    X, Y = Var("X"), Var("Y")
    q1 = [Atom("p", (X, Y)), Atom("p", (Y, X))]
    q2 = [Atom("p", (X, Y))]
    assert cq_contained([X], q1, [X], q2)
    assert not cq_contained([X], q2, [X], q1)


def test_mgu():
    X, Y, Z = Var("X"), Var("Y"), Var("Z")
    th = mgu([Atom("p", (X, "c")), Atom("p", ("d", Y))])
    assert th[X] == "d" and th[Y] == "c"
    assert mgu([Atom("p", ("a",)), Atom("p", ("b",))]) is None
    th2 = mgu([Atom("p", (X, X)), Atom("p", (Y, Z))])
    # all three variables collapse to one class
    vals = {th2.get(v, v) for v in (X, Y, Z)}
    assert len(vals) == 1
