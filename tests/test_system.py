"""End-to-end behaviour tests: the paper's running example through both the
symbolic layer and the vectorized engine, plus the cross-layer agreement."""
import numpy as np
import pytest

from repro.core.chase import chase
from repro.core.eg import evaluate, is_tg_for
from repro.core.terms import example1_program, parse_atom, parse_program
from repro.core.tg_datalog import tgmat
from repro.core.tg_linear import min_linear, tglinear
from repro.core.unify import entails
from repro.engine.materialize import EngineKB, materialize


def test_paper_example1_end_to_end():
    """Example 1/16/41/42: chase, tglinear -> G1, minLinear -> G2,
    TG-guided reasoning preserves BCQ answers with fewer triggers."""
    P = example1_program()
    B = [parse_atom("r(c1, c2)")]

    ch = chase(P, B, variant="restricted")
    assert ch.rounds == 2 and ch.derived == 3

    G1 = tglinear(P)
    assert is_tg_for(G1, P, B)
    G2 = min_linear(G1)
    assert len(G2.nodes) < len(G1.nodes)
    assert is_tg_for(G2, P, B)

    ev = evaluate(G2, B)
    assert ev.triggers < ch.triggers


def test_symbolic_vs_engine_agreement():
    P = parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
        T(X, Y) -> S(Y, X)
        S(Y, X) -> T(X, Y)
    """)
    rng = np.random.default_rng(11)
    B = [parse_atom(f"e(v{a}, v{b})")
         for a, b in rng.integers(0, 15, (25, 2))]
    ch = chase(P, B)
    I, _, st_sym = tgmat(P, B)
    kb = EngineKB(P, B)
    st_eng = materialize(kb, mode="tg")
    assert set(I.facts) == set(ch.facts)
    assert kb.decode_facts() == set(ch.facts) | set(B)


def test_trigger_metric_ordering():
    """GLog's central empirical claim (C4): TG-guided execution computes at
    most as many triggers as the SNE chase, usually fewer."""
    P = parse_program("""
        r(X, Y) -> R(X, Y)
        R(X, Y) -> S(Y, X)
        S(Y, X) -> R(X, Y)
        R(X, Y) & r(Y, Z) -> R(X, Z)
    """)
    rng = np.random.default_rng(5)
    B = [parse_atom(f"r(v{a}, v{b})")
         for a, b in rng.integers(0, 12, (30, 2))]
    ch = chase(P, B)
    I, _, st = tgmat(P, B)
    assert set(I.facts) == set(ch.facts)
    assert st["triggers"] <= ch.triggers
