"""Vectorized engine: relational ops vs numpy oracles + materialization
modes vs the symbolic chase."""
import numpy as np
import pytest

from repro.core.chase import chase
from repro.core.terms import parse_atom, parse_program
from repro.core.tg_linear import min_linear, tglinear
from repro.core.unify import entails
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import PAD, Relation


def _rel(rows):
    return Relation.from_numpy(np.asarray(rows, np.int32))


def test_dedup():
    r = _rel([[1, 2], [1, 2], [3, 4], [1, 2], [3, 5]])
    d = ops.dedup(r)
    assert d.count == 3
    assert d.rows_set() == {(1, 2), (3, 4), (3, 5)}


def test_dedup_idempotent():
    r = _rel([[5, 1], [5, 1], [2, 2]])
    d1 = ops.dedup(r)
    d2 = ops.dedup(d1)
    assert d1.rows_set() == d2.rows_set()


def test_filter_rows():
    r = _rel([[1, 1, 7], [1, 2, 7], [3, 3, 9]])
    f = ops.filter_rows(r, eq_pairs=((0, 1),))
    assert f.rows_set() == {(1, 1, 7), (3, 3, 9)}
    f2 = ops.filter_rows(r, const_pairs=((2, 7),))
    assert f2.count == 2


def test_sm_join_against_numpy():
    rng = np.random.default_rng(0)
    l = rng.integers(0, 10, (40, 2)).astype(np.int32)
    r = rng.integers(0, 10, (30, 2)).astype(np.int32)
    out, m = ops.sm_join(_rel(l), _rel(r), lkey=1, rkey=0)
    expect = {(a, b, c, d) for a, b in l for c, d in r if b == c}
    assert out.rows_set() == expect
    assert m == len([1 for a, b in l for c, d in r if b == c])


def test_antijoin():
    r = _rel([[1, 2], [3, 4], [5, 6]])
    hay = _rel([[3, 4], [9, 9]])
    a = ops.antijoin(r, hay)
    assert a.rows_set() == {(1, 2), (5, 6)}
    # column-projected antijoin
    a2 = ops.antijoin(r, _rel([[2], [6]]), cols=(1,))
    assert a2.rows_set() == {(3, 4)}


def test_union_dedup():
    a = _rel([[1, 1], [2, 2]])
    b = _rel([[2, 2], [3, 3]])
    u = ops.union(a, b)
    assert u.rows_set() == {(1, 1), (2, 2), (3, 3)}


TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


@pytest.mark.parametrize("mode", ["seminaive", "tg"])
def test_materialize_matches_chase(mode):
    rng = np.random.default_rng(3)
    B = [parse_atom(f"e(v{a}, v{b})")
         for a, b in rng.integers(0, 25, (50, 2))]
    ch = chase(TC, B)
    kb = EngineKB(TC, B)
    st = materialize(kb, mode=mode)
    assert kb.decode_facts() == set(ch.facts) | set(B)


def test_tg_mode_fewer_or_equal_triggers():
    P = parse_program("""
        a(X) & b(X) -> A(X)
        ap(X) & bp(X) -> A(X)
        A(X) & e(X, Y) -> A(Y)
    """)
    B = ([parse_atom(f"a(x{i})") for i in range(50)]
         + [parse_atom(f"b(x{i})") for i in range(50)]
         + [parse_atom(f"ap(x{i})") for i in range(50)]
         + [parse_atom(f"bp(x{i})") for i in range(40)]
         + [parse_atom(f"e(x{i}, x{i+1})") for i in range(20)])
    kb1 = EngineKB(P, B)
    st1 = materialize(kb1, mode="seminaive")
    kb2 = EngineKB(P, B)
    st2 = materialize(kb2, mode="tg")
    assert kb1.decode_facts() == kb2.decode_facts()
    assert st2.triggers <= st1.triggers


def test_tg_linear_engine_complete():
    P = parse_program("""
        r(X, Y) -> R(X, Y)
        R(X, Y) -> T(Y, X, Y)
        T(Y, X, Y) -> R(X, Y)
        r(X, Y) -> exists Z. T(Y, X, Z)
    """)
    B = [parse_atom(f"r(a{i}, b{i})") for i in range(10)]
    G = min_linear(tglinear(P))
    for cleaning in (True, False):
        kb = EngineKB(P, B)
        st = materialize(kb, mode="tg_linear", tg_eg=G, cleaning=cleaning)
        ch = chase(P, B, variant="restricted")
        assert entails(kb.decode_facts(), ch.facts)


def test_engine_skolem_existentials():
    P = parse_program("""
        p(X, Y) -> Q(X, Y)
        Q(X, Y) & Q(Y, Z) -> exists W. Q(Z, W)
    """)
    B = [parse_atom("p(a, b)"), parse_atom("p(b, c)")]
    kb = EngineKB(P, B)
    st = materialize(kb, mode="tg", max_rounds=5)
    facts = kb.decode_facts()
    # skolem chase on same program, bounded
    ch = chase(P, B, variant="skolem", max_rounds=5)
    assert len([f for f in facts if f.pred == "Q"]) == \
        len([f for f in ch.facts if f.pred == "Q"])
