"""Property tests for the distributed fixed-capacity bucket exchange.

``_route_to_buckets`` is the pure bucketization half of ``_exchange`` (the
other half is a bare ``all_to_all``), so its contract is testable without a
mesh: routed rows are exactly the kept valid inputs in stable input order,
``dropped_count`` is exact under forced bucket overflow, and the driver's
double-and-retry loop converges end-to-end.  Seeded cases always run;
hypothesis widens the search when installed (the CI dev extra).
"""
import numpy as np
import pytest

from repro.core.terms import parse_atom, parse_program
from repro.engine import ops
from repro.engine.distributed import _route_to_buckets
from repro.engine.materialize import EngineKB, materialize

NP_PAD = np.iinfo(np.int32).max


def _oracle(rows, target, ndev, bucket_cap):
    """First-come bucket placement with exact overflow accounting."""
    buckets = [[] for _ in range(ndev)]
    dropped = 0
    for r, t in zip(rows, target):
        if r[0] == NP_PAD:
            continue
        if len(buckets[int(t)]) < bucket_cap:
            buckets[int(t)].append([int(x) for x in r])
        else:
            dropped += 1
    return buckets, dropped


def _random_case(rng):
    n = int(rng.integers(1, 65))
    ar = int(rng.integers(1, 4))
    ndev = int(rng.integers(1, 9))
    bucket_cap = int(rng.integers(1, 17))
    rows = rng.integers(0, 40, (n, ar)).astype(np.int32)
    rows[rng.random(n) < 0.3] = NP_PAD          # invalid rows -> discarded
    target = rng.integers(0, ndev, n).astype(np.int32)
    return rows, target, ndev, bucket_cap


def _check_route(rows, target, ndev, bucket_cap):
    import jax.numpy as jnp
    got, drop = _route_to_buckets(jnp.asarray(rows), jnp.asarray(target),
                                  ndev, bucket_cap)
    got, drop = np.asarray(got), int(drop)
    exp_buckets, exp_drop = _oracle(rows, target, ndev, bucket_cap)
    # dropped_count is exact (including under forced overflow)
    assert drop == exp_drop
    placed = 0
    for d in range(ndev):
        block = got[d]
        k = len(exp_buckets[d])
        # valid rows are front-packed; everything past them is PAD
        assert (block[:k, 0] != NP_PAD).all()
        assert (block[k:, 0] == NP_PAD).all()
        # routed rows are exactly the kept inputs for this destination, in
        # stable input order (a permutation of the kept inputs overall)
        assert block[:k].tolist() == exp_buckets[d]
        placed += k
    n_valid = int((rows[:, 0] != NP_PAD).sum())
    assert placed + drop == n_valid


@pytest.mark.parametrize("seed", range(12))
def test_route_to_buckets_seeded(seed):
    rng = np.random.default_rng(3000 + seed)
    _check_route(*_random_case(rng))


def test_route_to_buckets_forced_overflow():
    """Every valid row targets one destination with a tiny bucket."""
    rows = np.arange(20, dtype=np.int32).reshape(10, 2)
    target = np.zeros(10, np.int32)
    _check_route(rows, target, ndev=4, bucket_cap=3)


def test_route_to_buckets_all_invalid():
    rows = np.full((8, 2), NP_PAD, np.int32)
    _check_route(rows, np.zeros(8, np.int32), ndev=2, bucket_cap=4)


def test_exchange_retry_loop_converges(monkeypatch):
    """End-to-end: with planted 4-row exchange buckets and 1-row delta
    buffers every early round overflows (the 1-row delta guarantees an
    overflow at ANY shard count: some shard always absorbs >= 2 fresh rows
    in round 1); the driver must double exactly the overflowed capacities,
    retry, and still reach the exact fixpoint."""
    from repro.engine import plan
    monkeypatch.setattr(plan, "_CAP_MEMO", {})

    def tiny_bucket(self, key):
        if key not in self.bucket:
            self.bucket[key] = 4
        return self.bucket[key]

    def tiny_delta(self, pred):
        if pred not in self.delta:
            self.delta[pred] = 1
        return self.delta[pred]
    monkeypatch.setattr(plan._Caps, "bucket_cap", tiny_bucket)
    monkeypatch.setattr(plan._Caps, "delta_cap", tiny_delta)

    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(14)] + \
        [parse_atom("e(v8, v2)")]
    kb_ref = EngineKB(TC, B)
    materialize(kb_ref, mode="tg")
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg", backend="dist")
    assert st.extra.get("dist") is True
    assert ops.HOST_SYNC_STATS.dist_retries >= 1
    assert kb.decode_facts() == kb_ref.decode_facts()
    # every pull is accounted for exactly once: host-stepped rounds +
    # host-stepped retries + fixpoint-program exits
    s = ops.HOST_SYNC_STATS
    assert s.dist_pulls == (st.rounds - s.dist_fixpoint_iters) \
        + s.dist_retries + s.dist_fixpoint_pulls


# ---------------------------------------------------------------------------
# hypothesis-driven cases (runs when the CI dev extra is installed)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2 ** 16))
    @settings(deadline=None, max_examples=25,
              suppress_health_check=[HealthCheck.too_slow])
    def test_route_to_buckets_hypothesis(seed):
        rng = np.random.default_rng(seed)
        _check_route(*_random_case(rng))
