"""tglinear (Alg. 1) + minLinear (Defs. 12-14) — paper Examples 1/16/41/42."""
import pytest

from repro.core.chase import chase
from repro.core.eg import evaluate, is_tg_for
from repro.core.terms import example1_program, parse_atom, parse_program
from repro.core.tg_linear import canonical_facts, min_linear, tglinear
from repro.core.unify import entails


def test_canonical_facts_bell():
    P = example1_program()
    H = canonical_facts(P)   # r/2: Bell(2) = 2 patterns
    assert len(H) == 2
    pats = {tuple(a == b for a in f.args for b in f.args) for f in H}
    assert len(pats) == 2


def test_example1_tglinear_structure():
    """Figure 1(b): nodes for r1, r4, r2 with r1 -> r2 edge."""
    P = example1_program()
    G = tglinear(P)
    rules = sorted(G.rule_of[v].name for v in G.nodes)
    assert rules == ["r1", "r2", "r4"]
    r2_node = [v for v in G.nodes if G.rule_of[v].name == "r2"][0]
    r1_node = [v for v in G.nodes if G.rule_of[v].name == "r1"][0]
    assert G.parents(r2_node) == {0: r1_node}


def test_example1_minlinear_removes_r4():
    """Figure 1(c): u2 (the r4 node) is dominated by u3 and removed."""
    P = example1_program()
    G = min_linear(tglinear(P))
    rules = sorted(G.rule_of[v].name for v in G.nodes)
    assert rules == ["r1", "r2"]


@pytest.mark.parametrize("base", [
    ["r(c1, c2)"],
    ["r(c1, c1)"],
    ["r(a, b)", "r(b, c)", "r(c, c)"],
])
def test_tg_property_preserved(base):
    P = example1_program()
    B = [parse_atom(s) for s in base]
    G = tglinear(P)
    assert is_tg_for(G, P, B)
    G2 = min_linear(G)
    assert is_tg_for(G2, P, B)


def test_example41_evaluation():
    """Example 41: node instances when reasoning over G1."""
    P = example1_program()
    G = tglinear(P)
    ev = evaluate(G, [parse_atom("r(c1, c2)")])
    by_rule = {G.rule_of[v].name: ev.node_facts[v] for v in G.nodes}
    assert {str(f) for f in by_rule["r1"]} == {"R(c1, c2)"}
    assert {str(f) for f in by_rule["r2"]} == {"T(c2, c1, c2)"}
    assert len(by_rule["r4"]) == 1
    (f,) = by_rule["r4"]
    assert f.pred == "T" and f.args[0] == "c2" and f.args[1] == "c1"


def test_linear_chain_program():
    P = parse_program("""
        a(X) -> B(X)
        B(X) -> C(X)
        C(X) -> D(X)
    """)
    G = min_linear(tglinear(P))
    assert G.stats()["nodes"] == 3 and G.stats()["depth"] == 2
    B = [parse_atom("a(u)"), parse_atom("a(v)")]
    assert is_tg_for(G, P, B)


def test_cyclic_linear_program_blocked():
    """r2/r3-style cycles must not yield infinite TGs (Example 2)."""
    P = parse_program("""
        r(X, Y) -> R(X, Y)
        R(X, Y) -> S(Y, X)
        S(Y, X) -> R(X, Y)
    """)
    G = tglinear(P)
    # the cycle closes after deriving R and S once: at most 3 nodes
    assert G.stats()["nodes"] <= 3
    assert is_tg_for(G, P, [parse_atom("r(c1, c2)")])
