"""Distributed materialization (shard_map): correctness on a multi-device
host mesh vs a python oracle.  Runs in a subprocess so the forced device
count doesn't leak into other tests."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys, json
    sys.path.insert(0, %r)
    import numpy as np, jax
    from repro.engine.distributed import run_distributed_tc, DistConfig
    from repro.launch.mesh import compat_make_mesh

    rng = np.random.default_rng(7)
    edges = np.unique(rng.integers(0, 40, (100, 2)).astype(np.int32), axis=0)
    mesh = compat_make_mesh((4, 1), ("data", "model"))
    cfg = DistConfig(shard_cap=1 << 12, delta_cap=1 << 10, bucket_cap=1 << 9)
    t_store, count, triggers, rounds = run_distributed_tc(edges, mesh, cfg)

    from collections import defaultdict
    adj = defaultdict(set)
    for a, b in edges:
        adj[a].add(b)
    closure = set(map(tuple, edges))
    frontier = set(closure)
    while frontier:
        new = set()
        for (x, y) in frontier:
            for z in adj[y]:
                if (x, z) not in closure:
                    new.add((x, z))
        closure |= new
        frontier = new
    rows = np.asarray(t_store)
    rows = rows[rows[:, 0] != np.iinfo(np.int32).max]
    got = set(map(tuple, rows.tolist()))
    print(json.dumps({"count": count, "expected": len(closure),
                      "match": got == {(int(a), int(b)) for a, b in closure},
                      "rounds": rounds, "triggers": triggers}))
""" % os.path.abspath(SRC))


def test_distributed_tc_4shards():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["match"], out
    assert out["count"] == out["expected"]
    assert out["triggers"] > 0 and out["rounds"] > 1
