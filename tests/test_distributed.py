"""Distributed materialization (shard_map over the shared rule-plan IR):
correctness on multi-device host meshes vs a python oracle, plus the
general-executor contracts (env routing, fragment fallback, store
invariant, one host pull per round).  Multi-device cases run in a
subprocess so the forced device count doesn't leak into other tests."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core.terms import parse_atom, parse_program
from repro.data.kb_sources import LUBM_LI, lubm_facts
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import lex_order

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys, json
    sys.path.insert(0, %r)
    import numpy as np
    from repro.engine.distributed import run_distributed_tc
    from repro.launch.mesh import make_data_mesh

    rng = np.random.default_rng(7)
    edges = np.unique(rng.integers(0, 40, (100, 2)).astype(np.int32), axis=0)
    mesh = make_data_mesh(4)
    rows, count, triggers, rounds = run_distributed_tc(edges, mesh)

    from collections import defaultdict
    adj = defaultdict(set)
    for a, b in edges:
        adj[a].add(b)
    closure = set(map(tuple, edges.tolist()))
    frontier = set(closure)
    while frontier:
        new = set()
        for (x, y) in frontier:
            for z in adj[y]:
                if (x, z) not in closure:
                    new.add((x, z))
        closure |= new
        frontier = new
    got = set(map(tuple, rows.tolist()))
    print(json.dumps({"count": count, "expected": len(closure),
                      "match": got == {(int(a), int(b)) for a, b in closure},
                      "rounds": rounds, "triggers": triggers}))
""" % os.path.abspath(SRC))


def test_distributed_tc_4shards():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["match"], out
    assert out["count"] == out["expected"]
    assert out["triggers"] > 0 and out["rounds"] > 1


def test_dist_general_program_inproc(monkeypatch):
    """The general executor (not just TC): LUBM-LI parity on the local
    mesh, with every scalar pull accounted for exactly once —
    host-stepped rounds + host-stepped retries + fixpoint-program
    exits."""
    monkeypatch.delenv("REPRO_DIST", raising=False)
    B = lubm_facts(n_univ=1)
    kb_ref = EngineKB(LUBM_LI, B)
    materialize(kb_ref, mode="tg")
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(LUBM_LI, B)
    st = materialize(kb, mode="tg", backend="dist")
    assert st.extra.get("dist") is True
    assert kb.decode_facts() == kb_ref.decode_facts()
    s = ops.HOST_SYNC_STATS
    assert s.dist_pulls == (st.rounds - s.dist_fixpoint_iters) \
        + s.dist_retries + s.dist_fixpoint_pulls


def test_dist_fixpoint_pulls_o_phases(monkeypatch):
    """Regression guard for the while_loop fixpoint: on deep-chain TC the
    round count is O(chain length) but the host pulls only at phase
    boundaries — dist_pulls must be O(phases), NOT O(rounds)."""
    monkeypatch.delenv("REPRO_DIST", raising=False)
    monkeypatch.delenv("REPRO_DIST_FIXPOINT", raising=False)
    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(64)]
    kb_ref = EngineKB(TC, B)
    materialize(kb_ref, mode="tg")
    # warm once so the capacity planner converges, then measure
    kb = EngineKB(TC, B)
    materialize(kb, mode="tg", backend="dist")
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg", backend="dist")
    assert kb.decode_facts() == kb_ref.decode_facts()
    s = ops.HOST_SYNC_STATS
    assert st.rounds > 60
    # the whole linear tail ran on-device: nearly every round was a loop
    # iteration, and the pull count collapsed to a handful of phase exits
    assert s.dist_fixpoint_iters >= st.rounds - 2
    assert s.dist_pulls <= 4
    assert s.dist_pulls == (st.rounds - s.dist_fixpoint_iters) \
        + s.dist_retries + s.dist_fixpoint_pulls


def test_dist_fixpoint_flag_off(monkeypatch):
    """REPRO_DIST_FIXPOINT=0 forces the host-stepped path: identical
    facts, one pull per round attempt, fixpoint counters untouched."""
    monkeypatch.delenv("REPRO_DIST", raising=False)
    monkeypatch.setenv("REPRO_DIST_FIXPOINT", "0")
    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(12)] + \
        [parse_atom("e(v7, v2)")]
    kb_ref = EngineKB(TC, B)
    materialize(kb_ref, mode="tg")
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg", backend="dist")
    assert kb.decode_facts() == kb_ref.decode_facts()
    s = ops.HOST_SYNC_STATS
    assert s.dist_fixpoint_pulls == s.dist_fixpoint_iters == 0
    assert s.dist_pulls == st.rounds + s.dist_retries


def test_dist_env_flag_routes(monkeypatch):
    """REPRO_DIST=1 selects the sharded backend without a backend arg."""
    monkeypatch.setenv("REPRO_DIST", "1")
    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    kb = EngineKB(TC, [parse_atom(f"e(v{i}, v{i+1})") for i in range(6)])
    st = materialize(kb, mode="tg")
    assert st.extra.get("dist") is True
    assert kb.rels["T"].count == 6 * 7 // 2


def test_dist_falls_back_outside_fragment(monkeypatch):
    """Existential rules are outside the plannable fragment: the dist
    backend declines and the two-phase executor produces the facts."""
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    P = parse_program("""
        p(X, Y) -> Q(X, Y)
        Q(X, Y) & Q(Y, Z) -> exists W. Q(Z, W)
    """)
    B = [parse_atom("p(a, b)"), parse_atom("p(b, c)")]
    kb_ref = EngineKB(P, B)
    materialize(kb_ref, mode="tg", max_rounds=5)
    kb = EngineKB(P, B)
    st = materialize(kb, mode="tg", max_rounds=5, backend="dist")
    assert st.extra.get("dist") is None
    assert kb.decode_facts() == kb_ref.decode_facts()


def test_dist_store_invariant(monkeypatch):
    """Distributed stores fold back lexsorted, compacted, set-semantic."""
    monkeypatch.delenv("REPRO_DIST", raising=False)
    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(10)] + \
        [parse_atom("e(v6, v2)"), parse_atom("e(v3, v3)")]
    kb = EngineKB(TC, B)
    materialize(kb, mode="tg", backend="dist")
    for pred, rel in kb.rels.items():
        assert rel.sorted_by == lex_order(rel.arity), pred
        rows = rel.np_rows()
        order = np.lexsort(rows.T[::-1])
        assert (order == np.arange(len(rows))).all(), pred
        assert len(rel.rows_set()) == rel.count, pred


def test_dist_midrun_restore_keeps_pulls_invariant(tmp_path, monkeypatch):
    """Kill-free rehearsal of crash recovery on the local mesh: run with
    checkpointing, rewind the checkpoint store to a mid-run tag, resume
    from a fresh KB — exact closure parity AND the per-round host-pull
    accounting (offset by the resumed rounds) must both survive."""
    from repro.engine import recovery
    monkeypatch.delenv("REPRO_DIST", raising=False)
    monkeypatch.delenv("REPRO_FAULT_SPEC", raising=False)
    monkeypatch.setenv("REPRO_CKPT_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_CKPT_KEEP", "100")
    TC = parse_program("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(24)] + \
        [parse_atom("e(v17, v3)")]
    kb1 = EngineKB(TC, B)
    st1 = materialize(kb1, mode="tg", backend="dist")
    assert st1.extra.get("dist") is True
    assert st1.extra.get("checkpoints", 0) >= 2

    mgr = recovery.RecoveryManager(str(tmp_path), keep=100)
    tags = mgr.tags()
    mid = tags[len(tags) // 2]
    assert 0 < mid < st1.rounds
    for t in tags:
        if t > mid:
            mgr.drop(t)

    ops.HOST_SYNC_STATS.reset()
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg", backend="dist")
    assert st2.extra.get("resumed_rounds") == mid
    assert st2.rounds == st1.rounds
    assert kb2.decode_facts() == kb1.decode_facts()
    s = ops.HOST_SYNC_STATS.snapshot()
    assert s.dist_pulls == (st2.rounds - mid - s.dist_fixpoint_iters) \
        + s.dist_retries + s.dist_fixpoint_pulls
