"""Checkpoint manager: save/restore round-trip, async save, resume, elastic
restore, preemption-driven exit, and the KB data pipeline state."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.data.pipeline import KBLinearizer, SyntheticTokens
from repro.launch.mesh import compat_make_mesh
from repro.models import model as M
from repro.models.layers import MeshCtx
from repro.train import optimizer as OPT
from repro.train.checkpoint import CheckpointManager
from repro.train.train_loop import train


def _mcx():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    return MeshCtx(mesh=mesh, dp=("data",), tp="model")


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, tree, extra={"step": 7}, blocking=True)
    assert mgr.latest_step() == 7
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, extra = mgr.restore(abstract)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=True)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_train_resume(tmp_path):
    cfg = get_smoke_config("stablelm_12b")
    mdl = M.build(cfg, _mcx())
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=32, seed=1)
    p1, o1, losses1 = train(mdl, data, steps=6, ckpt_dir=str(tmp_path),
                            ckpt_every=3, log_every=100, log=lambda *a: None)
    # second run resumes from step 6 checkpoint and continues to 8
    data2 = SyntheticTokens(cfg.vocab_size, batch=4, seq=32, seed=1)
    p2, o2, losses2 = train(mdl, data2, steps=8, ckpt_dir=str(tmp_path),
                            ckpt_every=3, log_every=100, log=lambda *a: None)
    assert data2.step >= 2   # only ran the remaining steps (6..8)
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() == 8


def test_kb_linearizer_stream():
    from repro.core.terms import parse_atom, parse_program
    from repro.engine.materialize import EngineKB, materialize
    P = parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(6)]
    kb = EngineKB(P, B)
    materialize(kb, mode="tg")
    lin = KBLinearizer(kb, batch=2, seq=16)
    b1 = lin.next()
    assert b1["tokens"].shape == (2, 16)
    assert b1["tokens"].max() < lin.vocab_size
    st = lin.state()
    b2 = lin.next()
    lin.restore(st)
    b2_again = lin.next()
    np.testing.assert_array_equal(b2["tokens"], b2_again["tokens"])


def test_elastic_restore_changes_sharding(tmp_path):
    """Checkpoint written replicated, restored with an explicit sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mcx = _mcx()
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree, blocking=True)
    abstract = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    sh = {"w": NamedSharding(mcx.mesh, P("data", None))}
    restored, _ = mgr.restore(abstract, sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16.0).reshape(4, 4))
