"""Chase variants (paper §3) incl. Example 1 behaviour."""
import pytest

from repro.core.chase import chase
from repro.core.terms import example1_program, parse_atom, parse_program
from repro.core.unify import entails


def test_example1_restricted():
    P = example1_program()
    B = [parse_atom("r(c1, c2)")]
    res = chase(P, B, variant="restricted")
    strs = {str(f) for f in res.facts}
    assert "R(c1, c2)" in strs
    assert "T(c2, c1, c2)" in strs
    assert any(s.startswith("T(c2, c1, _n") for s in strs)
    assert res.rounds == 2   # paper: stops in the 3rd round w/o new facts


def test_skolem_determinism():
    P = example1_program()
    B = [parse_atom("r(c1, c2)")]
    r1 = chase(P, B, variant="skolem")
    r2 = chase(P, B, variant="skolem")
    assert {str(f) for f in r1.facts} == {str(f) for f in r2.facts}


def test_equivalent_chase_terminates_fes():
    P = example1_program()
    B = [parse_atom("r(c1, c2)")]
    res = chase(P, B, variant="equivalent")
    assert res.terminated
    rr = chase(P, B, variant="restricted")
    assert entails(res.facts, rr.facts) and entails(rr.facts, res.facts)


def test_datalog_variants_agree():
    P = parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(5)]
    res_r = chase(P, B, variant="restricted")
    res_s = chase(P, B, variant="skolem")
    assert res_r.facts == res_s.facts
    t_facts = [f for f in res_r.facts if f.pred == "T"]
    assert len(t_facts) == 15     # all pairs i<j over the 6-node chain


def test_trigger_counts_monotone():
    P = parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(5)]
    res = chase(P, B)
    assert res.triggers >= res.derived


def test_nontermination_guard():
    P = parse_program("r(X, Y) -> exists Z. R(Y, Z)\nR(X, Y) -> exists Z. R(Y, Z)")
    B = [parse_atom("r(a, b)")]
    res = chase(P, B, variant="oblivious", max_rounds=5)
    assert not res.terminated and res.rounds == 5
