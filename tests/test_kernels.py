"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel bodies on CPU)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine.relation import PAD
from repro.kernels import ops as K
from repro.kernels import ref as R


@pytest.mark.parametrize("n,tile", [(64, 64), (256, 64), (1024, 256),
                                    (2048, 512), (4096, 4096)])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
def test_bitonic_sort_sweep(n, tile, dtype):
    rng = np.random.default_rng(n + tile)
    keys = jnp.asarray(rng.integers(0, 1 << 20, n).astype(dtype))
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = K.sort_with_payload(keys, vals, tile=tile)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys)))
    # payload is a permutation consistent with keys
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(vs)],
                                  np.asarray(ks))


@pytest.mark.parametrize("n,c,tile", [(128, 1, 64), (256, 2, 64),
                                      (512, 3, 128), (1024, 4, 256)])
def test_unique_mask_sweep(n, c, tile):
    rng = np.random.default_rng(n * c)
    data = rng.integers(0, 7, (n, c)).astype(np.int32)
    data = data[np.lexsort(data.T[::-1])]
    k = rng.integers(0, n // 4)
    if k:
        data[-k:] = np.iinfo(np.int32).max
        data = np.concatenate([data[:-k][np.lexsort(data[:-k].T[::-1])],
                               data[-k:]])
    got = K.unique_mask(jnp.asarray(data), tile=tile)
    want = R.unique_mask_ref(jnp.asarray(data))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nq,nh,tile", [(64, 16, 64), (256, 100, 128),
                                        (1024, 1, 256), (512, 511, 512)])
def test_probe_sweep(nq, nh, tile):
    rng = np.random.default_rng(nq + nh)
    hay = np.unique(rng.integers(0, 4 * nh, nh).astype(np.int32))
    q = jnp.asarray(rng.integers(0, 4 * nh, nq).astype(np.int32))
    got = K.probe_sorted(q, jnp.asarray(hay), tile=tile)
    want = R.probe_sorted_ref(q, jnp.asarray(hay))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sort_with_pad_sentinels():
    """PAD rows must sort to the end (engine invariant)."""
    n = 256
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 100, n).astype(np.int32)
    keys[200:] = np.iinfo(np.int32).max
    ks, _ = K.sort_with_payload(jnp.asarray(keys),
                                jnp.arange(n, dtype=jnp.int32), tile=64)
    assert (np.asarray(ks)[-56:] == np.iinfo(np.int32).max).all()


# ---------------------------------------------------------------------------
# edge shapes: empty inputs, non-pow2 lengths, all-PAD / all-duplicate data
# (the happy-path sweeps above only cover pow-2 engine buckets)
# ---------------------------------------------------------------------------
def test_sort_empty():
    ks, vs = K.sort_with_payload(jnp.zeros((0,), jnp.int32),
                                 jnp.zeros((0,), jnp.int32))
    assert ks.shape == (0,) and vs.shape == (0,)


@pytest.mark.parametrize("n", [1, 3, 96, 300, 1000])
def test_sort_non_pow2(n):
    rng = np.random.default_rng(n)
    keys = rng.integers(0, 1 << 20, n).astype(np.int32)
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = K.sort_with_payload(jnp.asarray(keys), vals, tile=64)
    want_k, _ = R.sort_with_payload_ref(jnp.asarray(keys), vals)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(want_k))
    # payload consistent with keys (no sentinel keys here, so the payload
    # is a permutation of [0, n))
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(vs)],
                                  np.asarray(ks))


def test_sort_all_pad():
    """All-PAD input: keys tie with the non-pow2 padding sentinels, but the
    payload must still be a permutation of the caller's."""
    for n in (64, 100):
        keys = jnp.full((n,), PAD, jnp.int32)
        ks, vs = K.sort_with_payload(keys, jnp.arange(n, dtype=jnp.int32))
        assert (np.asarray(ks) == PAD).all()
        assert sorted(np.asarray(vs).tolist()) == list(range(n))


def test_sort_non_pow2_with_pad_keys():
    """Non-pow2 input whose real keys include the padding sentinel: the
    synthetic padding entries must never leak into the payload (regression:
    keys=[5, PAD, 7] once returned payload index 3 for n=3)."""
    keys = jnp.array([5, PAD, 7], jnp.int32)
    ks, vs = K.sort_with_payload(keys, jnp.arange(3, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(ks), [5, 7, PAD])
    np.testing.assert_array_equal(np.asarray(vs), [0, 2, 1])
    rng = np.random.default_rng(11)
    keys = rng.integers(0, 50, 300).astype(np.int32)
    keys[rng.choice(300, 40, replace=False)] = PAD
    ks, vs = K.sort_with_payload(jnp.asarray(keys),
                                 jnp.arange(300, dtype=jnp.int32))
    np.testing.assert_array_equal(np.asarray(ks), np.sort(keys))
    vs = np.asarray(vs)
    assert sorted(vs.tolist()) == list(range(300))
    np.testing.assert_array_equal(keys[vs], np.asarray(ks))


def test_sort_all_duplicates():
    n = 256
    keys = jnp.full((n,), 7, jnp.int32)
    ks, vs = K.sort_with_payload(keys, jnp.arange(n, dtype=jnp.int32),
                                 tile=64)
    assert (np.asarray(ks) == 7).all()
    assert sorted(np.asarray(vs).tolist()) == list(range(n))


def test_unique_mask_empty():
    got = K.unique_mask(jnp.zeros((0, 2), jnp.int32))
    assert got.shape == (0,)


@pytest.mark.parametrize("n,c", [(1, 1), (96, 2), (300, 3), (1000, 2)])
def test_unique_mask_non_pow2(n, c):
    rng = np.random.default_rng(n + c)
    data = rng.integers(0, 5, (n, c)).astype(np.int32)
    data = data[np.lexsort(data.T[::-1])]
    got = K.unique_mask(jnp.asarray(data))
    want = R.unique_mask_ref(jnp.asarray(data))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_unique_mask_all_pad():
    data = jnp.full((128, 2), PAD, jnp.int32)
    got = K.unique_mask(data)
    assert (np.asarray(got) == 0).all()


def test_unique_mask_all_duplicates():
    data = jnp.tile(jnp.array([[3, 4]], jnp.int32), (256, 1))
    got = K.unique_mask(data, tile=64)
    want = R.unique_mask_ref(data)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got.sum()) == 1


def test_probe_empty_queries():
    got = K.probe_sorted(jnp.zeros((0,), jnp.int32),
                         jnp.arange(4, dtype=jnp.int32))
    assert got.shape == (0,)


def test_probe_empty_haystack():
    q = jnp.arange(64, dtype=jnp.int32)
    got = K.probe_sorted(q, jnp.zeros((0,), jnp.int32))
    assert (np.asarray(got) == 0).all()


@pytest.mark.parametrize("nq,nh", [(1, 1), (100, 37), (300, 3)])
def test_probe_non_pow2(nq, nh):
    rng = np.random.default_rng(nq * nh)
    hay = np.unique(rng.integers(0, 4 * nh, nh).astype(np.int32))
    q = jnp.asarray(rng.integers(0, 4 * nh, nq).astype(np.int32))
    got = K.probe_sorted(q, jnp.asarray(hay))
    want = R.probe_sorted_ref(q, jnp.asarray(hay))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_probe_all_pad_queries():
    """PAD queries only match a PAD entry in the haystack — against a
    valid-only haystack they must all miss."""
    q = jnp.full((64,), PAD, jnp.int32)
    hay = jnp.arange(16, dtype=jnp.int32)
    got = K.probe_sorted(q, hay)
    assert (np.asarray(got) == 0).all()


def test_probe_all_duplicate_haystack():
    q = jnp.array([4, 5, 6], jnp.int32)
    hay = jnp.full((32,), 5, jnp.int32)
    got = K.probe_sorted(q, hay)
    np.testing.assert_array_equal(np.asarray(got), [0, 1, 0])
