"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel bodies on CPU)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.engine.relation import PAD
from repro.kernels import ops as K
from repro.kernels import ref as R


@pytest.mark.parametrize("n,tile", [(64, 64), (256, 64), (1024, 256),
                                    (2048, 512), (4096, 4096)])
@pytest.mark.parametrize("dtype", [np.int32, np.uint32])
def test_bitonic_sort_sweep(n, tile, dtype):
    rng = np.random.default_rng(n + tile)
    keys = jnp.asarray(rng.integers(0, 1 << 20, n).astype(dtype))
    vals = jnp.arange(n, dtype=jnp.int32)
    ks, vs = K.sort_with_payload(keys, vals, tile=tile)
    np.testing.assert_array_equal(np.asarray(ks), np.sort(np.asarray(keys)))
    # payload is a permutation consistent with keys
    np.testing.assert_array_equal(np.asarray(keys)[np.asarray(vs)],
                                  np.asarray(ks))


@pytest.mark.parametrize("n,c,tile", [(128, 1, 64), (256, 2, 64),
                                      (512, 3, 128), (1024, 4, 256)])
def test_unique_mask_sweep(n, c, tile):
    rng = np.random.default_rng(n * c)
    data = rng.integers(0, 7, (n, c)).astype(np.int32)
    data = data[np.lexsort(data.T[::-1])]
    k = rng.integers(0, n // 4)
    if k:
        data[-k:] = np.iinfo(np.int32).max
        data = np.concatenate([data[:-k][np.lexsort(data[:-k].T[::-1])],
                               data[-k:]])
    got = K.unique_mask(jnp.asarray(data), tile=tile)
    want = R.unique_mask_ref(jnp.asarray(data))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("nq,nh,tile", [(64, 16, 64), (256, 100, 128),
                                        (1024, 1, 256), (512, 511, 512)])
def test_probe_sweep(nq, nh, tile):
    rng = np.random.default_rng(nq + nh)
    hay = np.unique(rng.integers(0, 4 * nh, nh).astype(np.int32))
    q = jnp.asarray(rng.integers(0, 4 * nh, nq).astype(np.int32))
    got = K.probe_sorted(q, jnp.asarray(hay), tile=tile)
    want = R.probe_sorted_ref(q, jnp.asarray(hay))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sort_with_pad_sentinels():
    """PAD rows must sort to the end (engine invariant)."""
    n = 256
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 100, n).astype(np.int32)
    keys[200:] = np.iinfo(np.int32).max
    ks, _ = K.sort_with_payload(jnp.asarray(keys),
                                jnp.arange(n, dtype=jnp.int32), tile=64)
    assert (np.asarray(ks)[-56:] == np.iinfo(np.int32).max).all()
