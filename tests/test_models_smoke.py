"""Per-architecture smoke tests: reduced configs, one train step + prefill +
decode on CPU, asserting output shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, get_config, get_smoke_config, SHAPES, \
    supported_cells
from repro.launch.mesh import compat_make_mesh
from repro.models import model as M
from repro.models.layers import MeshCtx
from repro.train import optimizer as OPT


def _mcx():
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    return MeshCtx(mesh=mesh, dp=("data",), tp="model")


def _batch(cfg, B=4, S=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    batch = {"labels": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        batch["embeddings"] = jax.random.normal(ks[1], (B, S, cfg.d_model),
                                                jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    mcx = _mcx()
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(0))
    opt = OPT.init_opt_state(params, mdl.opt_cfg)
    batch = _batch(cfg)
    new_p, new_o, metrics = jax.jit(mdl.train_step)(
        params, opt, batch, jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(new_p)[0]
    assert l0.shape == l1.shape
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_p)))
    assert changed


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder])
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    mcx = _mcx()
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(1))
    B, S = 4, 32
    batch = {k: v for k, v in _batch(cfg, B, S).items() if k != "labels"}
    next_tok, caches = jax.jit(mdl.prefill_step)(params, batch)
    assert next_tok.shape == (B,)
    assert (np.asarray(next_tok) >= 0).all()
    assert (np.asarray(next_tok) < cfg.vocab_size).all()
    if cfg.input_mode == "embeddings":
        tok = jax.random.normal(jax.random.PRNGKey(2), (B, 1, cfg.d_model),
                                jnp.float32)
    else:
        tok = next_tok
    nt2, caches2 = jax.jit(mdl.decode_step)(
        params, caches, tok, jnp.array(S, jnp.int32))
    assert nt2.shape == (B,)
    assert (np.asarray(nt2) >= 0).all() and \
        (np.asarray(nt2) < cfg.vocab_size).all()
    # caches keep their structure
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


def test_decode_matches_forward_greedy():
    """Greedy continuation via decode == greedy via re-prefill (fp32)."""
    cfg = get_smoke_config("stablelm_12b").with_(dtype="float32")
    mcx = _mcx()
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(3))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0,
                                cfg.vocab_size)
    t1, caches = jax.jit(mdl.prefill_step)(params, {"tokens": tokens})
    # decode one token, then compare against prefill over the extended seq
    t2, _ = jax.jit(mdl.decode_step)(params, caches, t1,
                                     jnp.array(S, jnp.int32))
    ext = jnp.concatenate([tokens, t1[:, None]], axis=1)
    t2_ref, _ = jax.jit(mdl.prefill_step)(params, {"tokens": ext})
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t2_ref))


def test_decode_matches_forward_ssm():
    cfg = get_smoke_config("falcon_mamba_7b").with_(dtype="float32")
    mcx = _mcx()
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(5))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, S), 0,
                                cfg.vocab_size)
    t1, caches = jax.jit(mdl.prefill_step)(params, {"tokens": tokens})
    t2, _ = jax.jit(mdl.decode_step)(params, caches, t1,
                                     jnp.array(S, jnp.int32))
    ext = jnp.concatenate([tokens, t1[:, None]], axis=1)
    t2_ref, _ = jax.jit(mdl.prefill_step)(params, {"tokens": ext})
    np.testing.assert_array_equal(np.asarray(t2), np.asarray(t2_ref))


def test_param_counts_sane():
    """param_counts() roughly matches the advertised model size."""
    expect = {
        "falcon_mamba_7b": 7e9, "command_r_35b": 35e9,
        "nemotron_4_340b": 340e9, "stablelm_12b": 12e9,
        "starcoder2_15b": 15e9, "qwen3_moe_30b_a3b": 30e9,
        "deepseek_v3_671b": 671e9, "zamba2_1p2b": 1.2e9,
        "hubert_xlarge": 1e9, "internvl2_1b": 0.6e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).param_counts()["total"]
        assert 0.4 * target < n < 2.1 * target, (arch, n, target)


def test_supported_cells_matrix():
    rows = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        cells = supported_cells(cfg)
        rows[arch] = [s for s, (ok, _) in cells.items() if ok]
    assert "long_500k" in rows["falcon_mamba_7b"]
    assert "long_500k" in rows["zamba2_1p2b"]
    assert "long_500k" not in rows["command_r_35b"]
    assert "decode_32k" not in rows["hubert_xlarge"]
    total = sum(len(v) for v in rows.values())
    assert total == 31   # 40 cells - 7 long_500k skips - 2 hubert decode/long


@pytest.mark.parametrize("arch,flags", [
    ("stablelm_12b", {"flash_vjp": True, "explicit_tp": True}),
    ("qwen3_moe_30b_a3b", {"flash_vjp": True, "moe_dispatch": "a2a"}),
    ("deepseek_v3_671b", {"flash_vjp": True}),
])
def test_smoke_perf_variants(arch, flags):
    """The §Perf hillclimb paths stay numerically sane on CPU."""
    cfg = get_smoke_config(arch).with_(**flags)
    mcx = _mcx()
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(0))
    opt = OPT.init_opt_state(params, mdl.opt_cfg)
    batch = _batch(cfg)
    _, _, metrics = jax.jit(mdl.train_step)(
        params, opt, batch, jnp.zeros((), jnp.int32))
    assert np.isfinite(float(metrics["loss"]))


def test_flash_vjp_matches_baseline_loss():
    cfg0 = get_smoke_config("stablelm_12b").with_(dtype="float32")
    cfg1 = cfg0.with_(flash_vjp=True)
    mcx = _mcx()
    m0, m1 = M.build(cfg0, mcx), M.build(cfg1, mcx)
    params = m0.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg0)
    l0, _ = m0.loss_fn(params, batch)
    l1, _ = m1.loss_fn(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
