"""Incremental maintenance (``materialize_delta``) + PR-8 bugfix tests.

Covers the DRed deletion path (over-delete / rescue / re-derive), the
seeded semi-naive insertion path (fused and two-phase), warm capacity-plan
reuse across delta calls, and the three satellite bugfixes: unambiguous
null rendering in ``Dictionary.decode``, unconditional base-relation dedup
in ``EngineKB.__init__``, and vectorized skolem allocation in
``execute_rule``.
"""
import numpy as np
import pytest

from repro.core import unify
from repro.core.terms import Null, parse_atom, parse_program
from repro.engine import ops, plan
from repro.engine.dictionary import Dictionary
from repro.engine.materialize import EngineKB, materialize
from repro.engine.ops import HOST_SYNC_STATS
from repro.engine.relation import Relation

TC = "e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)"


def _chain(n, pred="e", prefix="n"):
    return [parse_atom(f"{pred}({prefix}{i}, {prefix}{i + 1})")
            for i in range(n)]


def _scratch(P, facts):
    kb = EngineKB(parse_program(P) if isinstance(P, str) else P, facts)
    materialize(kb)
    return kb


# ---------------------------------------------------------------------------
# satellite 1: Dictionary null rendering is unambiguous
# ---------------------------------------------------------------------------
def test_dictionary_null_roundtrip():
    d = Dictionary()
    c = d.encode("_sk1")          # a genuine constant that LOOKS like a null
    n = d.skolem(("r", "Z", (c,)))
    assert n < 0 and c >= 0
    assert d.decode(n) == Null(-n)
    assert d.decode(c) == "_sk1"
    assert d.decode(n) != d.decode(c)          # the PR-8 collision, fixed
    for i in (c, n):
        assert d.encode(d.decode(i)) == i      # roundtrip both ranges
    assert d.skolem(("r", "Z", (c,))) == n     # memoized


def test_dictionary_rejects_foreign_null():
    d = Dictionary()
    with pytest.raises(ValueError):
        d.encode(Null(7))          # never allocated by this dictionary


def test_decoded_facts_render_nulls_as_nulls():
    kb = _scratch("r(X, Y) -> s(Y, Z)", [parse_atom("r(a, _sk1)")])
    facts = kb.decode_facts()
    nulls = {t for f in facts for t in f.args if isinstance(t, Null)}
    assert len(nulls) == 1                     # one existential frontier
    consts = {t for f in facts for t in f.args if not isinstance(t, Null)}
    assert "_sk1" in consts                    # the constant survives as-is


# ---------------------------------------------------------------------------
# satellite 2: base dedup on both store paths
# ---------------------------------------------------------------------------
def test_base_dedup_both_store_paths(monkeypatch):
    dup = [parse_atom("e(a, b)")] * 3 + _chain(4, prefix="c")
    counts = {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_SORTED_STORE", flag)
        kb = EngineKB(parse_program(TC), dup)
        assert kb.rels["e"].count == 5          # deduped at load on BOTH paths
        materialize(kb)
        counts[flag] = kb.num_facts()
    assert counts["0"] == counts["1"]


# ---------------------------------------------------------------------------
# satellite 3: vectorized skolem projection allocates per distinct frontier
# ---------------------------------------------------------------------------
def test_skolem_vectorized_null_count():
    P = "r(X, Y) -> s(X, Z)"
    facts = [parse_atom(f"r(a{i % 4}, b{i})") for i in range(32)]
    kb = _scratch(P, facts)
    # 4 distinct frontier values X -> 4 nulls, regardless of 32 rows
    assert kb.dict.num_nulls == 4
    assert len(kb.decode_facts()) == 32 + 4


# ---------------------------------------------------------------------------
# tentpole: insertions
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ("0", "1"))
def test_insert_only_matches_scratch(fused, monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", fused)
    base = _chain(10)
    kb = _scratch(TC, base)
    extra = [parse_atom("e(n10, n11)"), parse_atom("e(x, n0)")]
    st = kb.materialize_delta(insertions=extra)
    assert st.extra["delta"] and st.extra["inserted"] == 2
    assert kb.decode_facts() == _scratch(TC, base + extra).decode_facts()


def test_insert_into_unknown_predicate(monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", "0")
    kb = _scratch(TC, _chain(3))
    kb.materialize_delta(insertions=[parse_atom("iso(a, b)")])
    assert parse_atom("iso(a, b)") in kb.decode_facts()


def test_insert_existing_fact_is_noop():
    kb = _scratch(TC, _chain(5))
    before = kb.decode_facts()
    st = kb.materialize_delta(insertions=[parse_atom("e(n1, n2)")])
    assert kb.decode_facts() == before
    assert st.extra["propagated"] == 0


# ---------------------------------------------------------------------------
# tentpole: deletions (DRed)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("fused", ("0", "1"))
def test_delete_only_matches_scratch(fused, monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", fused)
    base = _chain(10)
    kb = _scratch(TC, base)
    st = kb.materialize_delta(deletions=[parse_atom("e(n4, n5)")])
    assert st.extra["over_deleted"] > 0
    ref = _scratch(TC, base[:4] + base[5:])
    assert kb.decode_facts() == ref.decode_facts()


def test_delete_rederivable_fact_is_noop():
    # T(n0,n1) is derived from base e(n0,n1): DRed over-deletes it, the
    # rescue pass re-derives it, and the store is unchanged.
    kb = _scratch(TC, _chain(6))
    before = kb.decode_facts()
    st = kb.materialize_delta(deletions=[parse_atom("T(n0, n1)")])
    assert kb.decode_facts() == before
    assert st.extra["over_deleted"] >= 1 and st.extra["rescued"] >= 1


def test_delete_with_alternative_path():
    # two parallel edges derive T(a,c); deleting one leaves T(a,c) alive
    base = [parse_atom(s) for s in
            ("e(a, b)", "e(b, c)", "e(a, c)")]
    kb = _scratch(TC, base)
    kb.materialize_delta(deletions=[parse_atom("e(b, c)")])
    ref = _scratch(TC, [base[0], base[2]])
    assert kb.decode_facts() == ref.decode_facts()
    assert parse_atom("T(a, c)") in kb.decode_facts()


def test_delete_absent_fact_is_noop():
    kb = _scratch(TC, _chain(4))
    before = kb.decode_facts()
    st = kb.materialize_delta(deletions=[parse_atom("e(zz, qq)")])
    assert kb.decode_facts() == before
    assert st.extra["over_deleted"] == 0


def test_mixed_insert_delete_same_call(monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", "1")
    base = _chain(8)
    kb = _scratch(TC, base)
    st = kb.materialize_delta(insertions=[parse_atom("e(m, n0)")],
                              deletions=[parse_atom("e(n3, n4)")])
    ref = _scratch(TC, [parse_atom("e(m, n0)")] + base[:3] + base[4:])
    assert kb.decode_facts() == ref.decode_facts()
    assert st.extra["inserted"] == 1 and st.extra["deleted"] == 1


def test_fact_in_both_batches_survives():
    base = _chain(5)
    kb = _scratch(TC, base)
    kb.materialize_delta(insertions=[parse_atom("e(n2, n3)")],
                         deletions=[parse_atom("e(n2, n3)")])
    assert kb.decode_facts() == _scratch(TC, base).decode_facts()


def test_insert_then_delete_roundtrip():
    base = _chain(7)
    kb = _scratch(TC, base)
    before = kb.decode_facts()
    f = parse_atom("e(q, n0)")
    kb.insert_facts([f])
    assert kb.decode_facts() != before
    kb.delete_facts([f])
    assert kb.decode_facts() == before


# ---------------------------------------------------------------------------
# existential programs (null isomorphism, not equality)
# ---------------------------------------------------------------------------
def test_existential_incremental_isomorphic():
    P = "r(X, Y) -> s(Y, Z)\ns(X, Y) & r(Y, W) -> s(X, V)"
    kb = _scratch(P, [parse_atom("r(a, b)")])
    kb.materialize_delta(insertions=[parse_atom("r(c, a)")])
    ref = _scratch(P, [parse_atom("r(a, b)"), parse_atom("r(c, a)")])
    assert unify.equivalent(kb.decode_facts(), ref.decode_facts())


def test_existential_delete_isomorphic():
    P = "r(X, Y) -> s(Y, Z)"
    base = [parse_atom("r(a, b)"), parse_atom("r(c, d)")]
    kb = _scratch(P, base)
    kb.materialize_delta(deletions=[parse_atom("r(c, d)")])
    ref = _scratch(P, base[:1])
    assert unify.equivalent(kb.decode_facts(), ref.decode_facts())


# ---------------------------------------------------------------------------
# warm plan reuse: second delta call must not retry or re-plan
# ---------------------------------------------------------------------------
def test_shallow_delta_stays_two_phase(monkeypatch):
    # a disconnected edge converges in 2 rounds — below the fused handoff
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = _scratch(TC, _chain(8))
    st = kb.materialize_delta(insertions=[parse_atom("e(w0, w1)")])
    assert st.rounds <= 3 and "fused" not in st.extra


def test_deep_cascade_hands_off_to_fused_warm_no_retries(monkeypatch):
    # PREPENDING a chain edge cascades one closure hop per round (appending
    # converges in 2 — every ancestor already reaches the old end), so the
    # cascade must hand off to the fused fixpoint; a second same-shaped
    # delta must reuse the warm capacity plans (zero retries)
    monkeypatch.setenv("REPRO_FUSED", "1")
    monkeypatch.setattr(plan, "_CAP_MEMO", {})
    base = _chain(16)
    kb = _scratch(TC, base)
    w1 = parse_atom("e(w1, n0)")
    st = kb.materialize_delta(insertions=[w1])
    assert st.extra.get("fused")
    assert kb.decode_facts() == _scratch(TC, base + [w1]).decode_facts()
    r0 = HOST_SYNC_STATS.fused_retries
    w2 = parse_atom("e(w2, w1)")
    st2 = kb.materialize_delta(insertions=[w2])
    assert st2.extra.get("fused")
    assert HOST_SYNC_STATS.fused_retries == r0
    assert kb.decode_facts() == _scratch(TC, base + [w1, w2]).decode_facts()


# ---------------------------------------------------------------------------
# new ops: merge_diff / semijoin
# ---------------------------------------------------------------------------
def _rel(rows):
    a = np.asarray(rows, np.int32)
    return Relation.from_numpy(a.reshape(len(rows), -1))


def test_merge_diff_basic():
    a = _rel([[1, 2], [3, 4], [5, 6], [7, 8]])
    b = _rel([[3, 4], [7, 8], [9, 9]])
    d = ops.merge_diff(a, b)
    assert d.rows_set() == {(1, 2), (5, 6)} and d.count == 2
    assert d.is_lexsorted
    assert ops.merge_diff(a, a).count == 0
    assert ops.merge_diff(a, _rel([[0, 0]])).rows_set() == a.rows_set()


def test_merge_diff_empty_sides():
    a = _rel([[1, 2]])
    assert ops.merge_diff(a, Relation.empty(2)).rows_set() == {(1, 2)}
    assert ops.merge_diff(Relation.empty(2), a).count == 0


def test_semijoin_basic():
    a = _rel([[1, 2], [3, 4], [5, 6]])
    b = _rel([[3, 4], [9, 9]])
    assert ops.semijoin(a, b).rows_set() == {(3, 4)}
    assert ops.semijoin(a, Relation.empty(2)).count == 0
    assert ops.semijoin(Relation.empty(2), b).count == 0
    # column-projected probe: match on first column only
    c = _rel([[3], [5]])
    assert ops.semijoin(a, c, cols=(0,)).rows_set() == {(3, 4), (5, 6)}
