"""Fault tolerance: durable checkpoint store (atomicity, checksums,
fallback), deterministic fault injection, bounded retry budgets with
graceful spill, atomic streamed ingest, and full kill-9 / SIGTERM
crash-resume parity.  Crash cases run in subprocesses (the fault really
kills the process); everything else is in-process."""
import json
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.terms import parse_atom, parse_program
from repro.engine import faultinject, ops, plan, recovery
from repro.engine.fused import materialize_fused
from repro.engine.materialize import EngineKB, materialize

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def _chain(n, extra=0, seed=0):
    rng = np.random.default_rng(seed)
    edges = [(i, i + 1) for i in range(n)]
    edges += [tuple(e) for e in rng.integers(0, n, (extra, 2))]
    return [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


def _payload(i):
    return [{"store__e": (np.arange(6, dtype=np.int32) + i).reshape(3, 2)}]


# ---------------------------------------------------------------------------
# RecoveryManager: atomic save, checksum validation, fallback, GC
# ---------------------------------------------------------------------------
def test_manager_save_load_roundtrip(tmp_path):
    mgr = recovery.RecoveryManager(str(tmp_path), keep=10)
    mgr.save(1, {"fingerprint": "fp", "rounds": 1}, _payload(1),
             {"dict.pkl": b"one"})
    mgr.save(2, {"fingerprint": "fp", "rounds": 2}, _payload(2),
             {"dict.pkl": b"two"})
    assert mgr.tags() == [1, 2]
    meta, shards, blobs = mgr.load("fp")
    assert meta["rounds"] == 2
    np.testing.assert_array_equal(
        shards[0]["store__e"], (np.arange(6, dtype=np.int32) + 2).reshape(3, 2))
    assert blobs["dict.pkl"] == b"two"
    # fingerprint mismatch: a different program's checkpoints never restore
    assert mgr.load("other-fp") is None


def test_manager_corrupt_payload_falls_back(tmp_path):
    mgr = recovery.RecoveryManager(str(tmp_path), keep=10)
    mgr.save(1, {"fingerprint": "fp", "rounds": 1}, _payload(1), {})
    mgr.save(2, {"fingerprint": "fp", "rounds": 2}, _payload(2), {})
    faultinject.corrupt_file(os.path.join(mgr._path(2), "shard_0.npz"))
    meta, _, _ = mgr.load("fp")
    assert meta["rounds"] == 1        # checksum catches the flip, falls back
    faultinject.corrupt_file(os.path.join(mgr._path(1), "shard_0.npz"))
    assert mgr.load("fp") is None     # nothing valid left


def test_manager_corrupt_manifest_skipped(tmp_path):
    mgr = recovery.RecoveryManager(str(tmp_path), keep=10)
    mgr.save(1, {"fingerprint": "fp", "rounds": 1}, _payload(1), {})
    mgr.save(2, {"fingerprint": "fp", "rounds": 2}, _payload(2), {})
    with open(os.path.join(mgr._path(2), "MANIFEST.json"), "w") as f:
        f.write("{ not json")
    meta, _, _ = mgr.load("fp")
    assert meta["rounds"] == 1


def test_manager_gc_and_tmp_litter(tmp_path):
    mgr = recovery.RecoveryManager(str(tmp_path), keep=2)
    for t in range(1, 5):
        mgr.save(t, {"fingerprint": "fp", "rounds": t}, _payload(t), {})
    assert mgr.tags() == [3, 4]       # GC kept the newest `keep`
    # a crashed save leaves a .tmp dir and a manifest-less dir: both ignored
    os.makedirs(tmp_path / ".tmp_ckpt_00000009")
    os.makedirs(tmp_path / "ckpt_00000010")
    assert mgr.tags() == [3, 4]
    meta, _, _ = mgr.load("fp")
    assert meta["rounds"] == 4


# ---------------------------------------------------------------------------
# fault injection primitives
# ---------------------------------------------------------------------------
def test_faultspec_parsing():
    fs = faultinject.FaultSpec("crash:round=7,sleep:round=2:secs=0.5,storm")
    assert fs.active and fs.tiny_caps()
    assert fs._round_of("crash") == 7
    assert fs.events["sleep"] == {"round": "2", "secs": "0.5"}
    empty = faultinject.FaultSpec("")
    assert not empty.active and not empty.tiny_caps()
    empty.on_boundary(10)             # all hooks are no-ops when empty


def test_corrupt_file_flips_one_byte(tmp_path):
    p = tmp_path / "blob"
    p.write_bytes(b"abcdefgh")
    faultinject.corrupt_file(str(p), seed=3)
    got = p.read_bytes()
    assert len(got) == 8 and sum(a != b for a, b in zip(got, b"abcdefgh")) == 1
    empty = tmp_path / "empty"
    empty.write_bytes(b"")
    faultinject.corrupt_file(str(empty))
    assert empty.read_bytes() == b"\xff"


def test_ckpt_corrupt_event_one_shot(tmp_path):
    mgr = recovery.RecoveryManager(str(tmp_path), keep=10)
    for t in (1, 2, 3):
        mgr.save(t, {"fingerprint": "fp", "rounds": t}, _payload(t), {})
    spec = faultinject.FaultSpec("ckpt_corrupt:tag=2")
    spec.on_checkpoint(mgr._path(1), 1)   # below the tag threshold: no-op
    assert mgr._load_one(1, "fp") is not None
    spec.on_checkpoint(mgr._path(2), 2)   # fires exactly here
    assert mgr._load_one(2, "fp") is None
    spec.on_checkpoint(mgr._path(3), 3)   # one-shot: tag 3 stays intact
    assert mgr._load_one(3, "fp") is not None
    mgr.drop(3)
    meta, _, _ = mgr.load("fp")           # skips the corrupt tag 2
    assert meta["rounds"] == 1


def test_preemption_guard_chains_previous_handler():
    seen = []
    prev = signal.signal(signal.SIGUSR1, lambda s, f: seen.append(s))
    try:
        from repro.train.fault import PreemptionGuard
        g = PreemptionGuard(signals=(signal.SIGUSR1,), chain=True)
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.01)
        assert g.requested
        assert seen == [signal.SIGUSR1]   # chained to the outer handler
        g.restore()
    finally:
        signal.signal(signal.SIGUSR1, prev)


def test_kb_fingerprint_identity():
    kb = EngineKB(TC, _chain(4))
    assert recovery.kb_fingerprint(kb, "tg") == \
        recovery.kb_fingerprint(EngineKB(TC, _chain(8)), "tg")
    assert recovery.kb_fingerprint(kb, "tg") != \
        recovery.kb_fingerprint(kb, "tg_noopt")


# ---------------------------------------------------------------------------
# dictionary rollback + atomic streamed ingest
# ---------------------------------------------------------------------------
def test_dictionary_mark_rollback():
    from repro.engine.dictionary import Dictionary
    d = Dictionary()
    base = d.encode_many(["a", "b", "c"])
    token = d.mark()
    d.encode_many(["x", "y"])
    assert len(d) == 5
    d.rollback(token)
    assert len(d) == 3
    assert [d.decode(i) for i in base] == ["a", "b", "c"]
    # re-interning after rollback hands out fresh consistent ids
    again = d.encode_many(["x", "a"])
    assert d.decode(again[0]) == "x" and d.decode(again[1]) == "a"


def test_dictionary_state_roundtrip():
    from repro.engine.dictionary import Dictionary
    d = Dictionary()
    ids = d.encode_many(["a", "b", 42])
    d2 = Dictionary()
    d2.load_state(d.state_dict())
    assert len(d2) == len(d)
    assert [d2.decode(i) for i in ids] == ["a", "b", 42]


def test_ingest_rejects_bad_arity_chunk_atomically():
    prog = parse_program("e(X, Y) -> T(X, Y)")
    kb = EngineKB(prog, ())
    kb.ingest_rows("e", np.array([["a", "b"], ["b", "c"]], dtype=object))
    n_terms, n_rows = len(kb.dict), kb.rels["e"].count
    with pytest.raises(ValueError, match="arity"):
        kb.ingest_rows("e", np.array([["x", "y", "z"]], dtype=object))
    assert len(kb.dict) == n_terms and kb.rels["e"].count == n_rows


def test_ingest_failed_chunk_rolls_back_then_retries(monkeypatch):
    prog = parse_program("e(X, Y) -> T(X, Y)")
    chunk1 = np.array([["a", "b"], ["b", "c"]], dtype=object)
    chunk2 = np.array([["b", "c"], ["c", "d"], ["d", "e"]], dtype=object)
    kb = EngineKB(prog, ())
    kb.ingest_rows("e", chunk1)
    n_terms, store = len(kb.dict), kb.rels["e"]

    orig = ops.merge_union
    calls = {"n": 0}

    def flaky_merge(a, b):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated mid-chunk failure")
        return orig(a, b)

    monkeypatch.setattr(ops, "merge_union", flaky_merge)
    with pytest.raises(RuntimeError, match="mid-chunk"):
        kb.ingest_rows("e", chunk2)
    # the failed chunk left no trace: dictionary AND store as before
    assert len(kb.dict) == n_terms
    assert kb.rels["e"] is store
    kb.ingest_rows("e", chunk2)       # retrying the same chunk succeeds

    ref = EngineKB(prog, [parse_atom(f"e({a}, {b})")
                          for a, b in [("a", "b"), ("b", "c"),
                                       ("c", "d"), ("d", "e")]])
    materialize(kb, mode="tg")
    materialize(ref, mode="tg")
    assert kb.decode_facts() == ref.decode_facts()


def test_host_sync_stats_snapshot():
    ops.HOST_SYNC_STATS.reset()
    ops.HOST_SYNC_STATS.fused_pulls = 3
    ops.HOST_SYNC_STATS.dist_retries = 2
    snap = ops.HOST_SYNC_STATS.snapshot()
    ops.HOST_SYNC_STATS.reset()
    assert snap is not ops.HOST_SYNC_STATS
    assert snap.fused_pulls == 3 and snap.dist_retries == 2
    assert ops.HOST_SYNC_STATS.fused_pulls == 0


# ---------------------------------------------------------------------------
# bounded retry budgets: diagnostics, storm, graceful spill
# ---------------------------------------------------------------------------
def test_retry_budget_escalates_and_raises(monkeypatch):
    monkeypatch.setenv("REPRO_MAX_RETRIES", "3")
    caps = plan._Caps.__new__(plan._Caps)
    caps.store, caps.delta, caps.tail = {}, {"T": 1}, {}
    caps.join, caps.bucket = {}, {}
    budget = plan.RetryBudget(caps, row_bytes=8)
    label = ("delta", "T")
    budget.overflow([label])          # streak 1: x2
    budget.overflow([label])          # streak 2: x2 (legacy trajectory)
    assert caps.delta["T"] == 4
    budget.overflow([label])          # streak 3: x2^1 twice
    assert caps.delta["T"] == 16
    with pytest.raises(plan.CapacityError) as ei:
        budget.overflow([label])
    assert ei.value.label == label and ei.value.requested_bytes > 0
    assert "REPRO_MAX_RETRIES" in str(ei.value)
    budget.ok()                       # progress resets the ladder
    budget.overflow([label])
    assert caps.delta["T"] == 32


def test_retry_budget_resident_ceiling():
    caps = plan._Caps.__new__(plan._Caps)
    caps.store, caps.delta = {}, {"T": 1 << 20}
    caps.tail, caps.join, caps.bucket = {}, {}, {}
    budget = plan.RetryBudget(caps, row_bytes=8, attempts=100,
                              resident_bytes=1 << 22)
    with pytest.raises(plan.CapacityError, match="REPRO_MAX_RESIDENT_MB"):
        budget.overflow([("delta", "T")])


def test_storm_exhausts_budget_with_diagnostic(monkeypatch):
    """Under a forced-overflow storm with a 1-attempt budget the fused
    executor must raise a diagnostic CapacityError (spill=False), return
    None (spill=True, no progress yet), and the materialize() entry point
    must still produce the right closure via fallback."""
    monkeypatch.setenv("REPRO_FAULT_SPEC", "storm")
    monkeypatch.setenv("REPRO_MAX_RETRIES", "1")
    monkeypatch.setattr(faultinject, "_CACHE", {})
    monkeypatch.setattr(plan, "_CAP_MEMO", {})
    monkeypatch.delenv("REPRO_CKPT_DIR", raising=False)
    # the planner's cold-start floor is 64 delta rows (one doubling: 128);
    # a >128-row extensional delta exhausts a 1-attempt ladder for certain
    B = _chain(200, extra=50, seed=1)
    with pytest.raises(plan.CapacityError) as ei:
        materialize_fused(EngineKB(TC, B), mode="tg", spill=False)
    assert ei.value.requested_bytes > 0 and ei.value.label is not None
    # cold-start overflow with spill on: clean fragment fallback (None)
    assert materialize_fused(EngineKB(TC, B), mode="tg") is None
    # end to end: the driver degrades to two-phase and converges
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg")
    assert st.extra.get("fused") is not True
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    monkeypatch.delenv("REPRO_FAULT_SPEC", raising=False)
    monkeypatch.setattr(faultinject, "_CACHE", {})
    ref = EngineKB(TC, B)
    materialize(ref, mode="tg")
    assert kb.decode_facts() == ref.decode_facts()


def test_midrun_capacity_spill_to_two_phase(monkeypatch):
    """A capacity ladder that diverges AFTER committed progress must not
    discard that progress: the fused executor writes back its last good
    state and the two-phase executor finishes the fixpoint."""
    prog = parse_program("""
        s(X) -> t(X)
        t(X) & e(X, Y) -> t(Y)
    """)
    B = [parse_atom("s(v0)")] + \
        [parse_atom(f"e(v0, w{i})") for i in range(100)]
    ref = EngineKB(prog, B)
    materialize(ref, mode="tg")

    monkeypatch.setenv("REPRO_MAX_RETRIES", "2")
    monkeypatch.setattr(plan, "_CAP_MEMO", {})
    monkeypatch.delenv("REPRO_CKPT_DIR", raising=False)

    # plant t's delta bucket so round 1 (1 fresh row) fits but the 100-row
    # fan-out round overflows past the 2-attempt ladder (8 -> 16 -> 32);
    # other preds (the normalizer's aux relations) keep a roomy bucket
    def small_delta(self, pred):
        if pred not in self.delta:
            self.delta[pred] = 8 if pred == "t" else 256
        return self.delta[pred]
    monkeypatch.setattr(plan._Caps, "delta_cap", small_delta)

    kb = EngineKB(prog, B)
    st = materialize_fused(kb, mode="tg")
    assert st is not None
    assert "spilled" in st.extra and "capacity bucket" in st.extra["spilled"]
    assert kb.decode_facts() == ref.decode_facts()


# ---------------------------------------------------------------------------
# in-process resume (two-phase and fused)
# ---------------------------------------------------------------------------
def _ckpt_env(monkeypatch, tmp_path):
    monkeypatch.delenv("REPRO_DIST", raising=False)
    monkeypatch.delenv("REPRO_FAULT_SPEC", raising=False)
    monkeypatch.setattr(faultinject, "_CACHE", {})
    monkeypatch.setenv("REPRO_CKPT_DIR", str(tmp_path))
    monkeypatch.setenv("REPRO_CKPT_KEEP", "100")


@pytest.mark.parametrize("fused", [False, True])
def test_midrun_resume_exact_parity(tmp_path, monkeypatch, fused):
    """Run to completion with checkpointing, rewind the checkpoint store
    to a mid-run tag, and resume with a FRESH process-state KB: the
    continued run must reach the identical closure and round count."""
    if fused:
        monkeypatch.setenv("REPRO_FUSED", "1")
    else:
        monkeypatch.delenv("REPRO_FUSED", raising=False)
    B = _chain(14, extra=6, seed=5)
    ref = EngineKB(TC, B)
    st_ref = materialize(ref, mode="tg")

    _ckpt_env(monkeypatch, tmp_path)
    kb1 = EngineKB(TC, B)
    st1 = materialize(kb1, mode="tg")
    assert st1.extra.get("checkpoints", 0) >= 2
    assert kb1.decode_facts() == ref.decode_facts()

    mgr = recovery.RecoveryManager(str(tmp_path), keep=100)
    tags = mgr.tags()
    mid = tags[len(tags) // 2]
    assert 0 < mid < st_ref.rounds
    for t in tags:
        if t > mid:
            mgr.drop(t)

    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg")
    assert st2.extra.get("resumed_rounds") == mid
    assert st2.rounds == st_ref.rounds
    assert kb2.decode_facts() == ref.decode_facts()


def test_resume_of_finished_run_is_noop(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", "1")
    B = _chain(10, extra=4, seed=2)
    ref = EngineKB(TC, B)
    st_ref = materialize(ref, mode="tg")

    _ckpt_env(monkeypatch, tmp_path)
    kb1 = EngineKB(TC, B)
    materialize(kb1, mode="tg")
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg")
    assert st2.extra.get("resumed_rounds") == st_ref.rounds
    assert st2.rounds == st_ref.rounds    # nothing re-derived
    assert kb2.decode_facts() == ref.decode_facts()


def test_cross_executor_restore(tmp_path, monkeypatch):
    """Checkpoints are executor-neutral host state: one written by the
    fused executor mid-run restores into the two-phase executor."""
    B = _chain(14, extra=6, seed=5)
    ref = EngineKB(TC, B)
    st_ref = materialize(ref, mode="tg")

    monkeypatch.setenv("REPRO_FUSED", "1")
    _ckpt_env(monkeypatch, tmp_path)
    kb1 = EngineKB(TC, B)
    materialize(kb1, mode="tg")
    mgr = recovery.RecoveryManager(str(tmp_path), keep=100)
    tags = mgr.tags()
    mid = tags[len(tags) // 2]
    assert 0 < mid < st_ref.rounds
    for t in tags:
        if t > mid:
            mgr.drop(t)

    monkeypatch.delenv("REPRO_FUSED", raising=False)   # resume on two-phase
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg")
    assert st2.extra.get("resumed_rounds") == mid
    assert st2.extra.get("resumed_from", (None,))[0] == "fused"
    assert st2.rounds == st_ref.rounds
    assert kb2.decode_facts() == ref.decode_facts()


# ---------------------------------------------------------------------------
# subprocess crash drills: SIGKILL / SIGTERM, single-device and elastic dist
# ---------------------------------------------------------------------------
_CRASH_SCRIPT = textwrap.dedent("""
    import sys
    sys.path.insert(0, %r)
    import numpy as np
    from repro.core.terms import parse_atom, parse_program
    from repro.engine.materialize import EngineKB, materialize

    TC = parse_program("e(X, Y) -> T(X, Y)\\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    rng = np.random.default_rng(5)
    edges = [(i, i + 1) for i in range(80)]
    edges += [tuple(e) for e in rng.integers(0, 80, (30, 2))]
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]
    kb = EngineKB(TC, B)
    materialize(kb, mode="tg")
    print("SURVIVED")
""" % SRC)

_RESUME_SCRIPT = textwrap.dedent("""
    import os, sys, json
    sys.path.insert(0, %r)
    ckpt = os.environ.pop("REPRO_CKPT_DIR")
    import numpy as np
    from repro.core.terms import parse_atom, parse_program
    from repro.engine.materialize import EngineKB, materialize

    TC = parse_program("e(X, Y) -> T(X, Y)\\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    rng = np.random.default_rng(5)
    edges = [(i, i + 1) for i in range(80)]
    edges += [tuple(e) for e in rng.integers(0, 80, (30, 2))]
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]

    ref = EngineKB(TC, B)                   # checkpoint env popped: clean run
    st_ref = materialize(ref, mode="tg")

    os.environ["REPRO_CKPT_DIR"] = ckpt
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg")
    print(json.dumps({
        "parity": kb.decode_facts() == ref.decode_facts(),
        "resumed_rounds": st.extra.get("resumed_rounds", 0),
        "rounds": st.rounds, "ref_rounds": st_ref.rounds,
    }))
""" % SRC)


def _run(script, env):
    full = {**os.environ, **env}
    full.pop("REPRO_FAULT_SPEC", None)
    full.update(env)
    return subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          env=full)


def test_sigkill_then_resume_fused_subprocess(tmp_path):
    """kill -9 mid-fixpoint; a fresh process resumes from the durable
    checkpoint and reaches the exact closure of an uninterrupted run."""
    env = {"REPRO_FUSED": "1", "REPRO_CKPT_DIR": str(tmp_path),
           "REPRO_CKPT_KEEP": "100"}
    r = _run(_CRASH_SCRIPT,
             {**env, "REPRO_FAULT_SPEC": "storm,crash:round=4"})
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert "SURVIVED" not in r.stdout
    assert recovery.RecoveryManager(str(tmp_path)).tags(), \
        "crash left no durable checkpoint behind"

    r = _run(_RESUME_SCRIPT, env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["parity"], out
    assert 1 <= out["resumed_rounds"] < out["rounds"]
    assert out["rounds"] == out["ref_rounds"]


def test_sigterm_checkpoints_and_exits_143_subprocess(tmp_path):
    """SIGTERM during the fused fixpoint: the guard is honored at the next
    host pull — the run saves a consistent checkpoint, exits 143, and a
    fresh process resumes to exact parity."""
    env = {"REPRO_FUSED": "1", "REPRO_CKPT_DIR": str(tmp_path),
           "REPRO_CKPT_KEEP": "100"}
    r = _run(_CRASH_SCRIPT,
             {**env, "REPRO_FAULT_SPEC": "storm,sigterm:round=3"})
    assert r.returncode == 143, (r.returncode, r.stderr[-2000:])
    assert "SURVIVED" not in r.stdout
    loaded = recovery.RecoveryManager(str(tmp_path)).load()
    assert loaded is not None, "exit 143 without a valid checkpoint"
    assert loaded[0]["rounds"] >= 1

    r = _run(_RESUME_SCRIPT, env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["parity"], out
    assert 1 <= out["resumed_rounds"] < out["rounds"]


_DIST_RESUME_SCRIPT = textwrap.dedent("""
    import os, sys, json
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    sys.path.insert(0, %r)
    ckpt = os.environ.pop("REPRO_CKPT_DIR")
    import numpy as np
    from repro.core.terms import parse_atom, parse_program
    from repro.engine import ops
    from repro.engine.materialize import EngineKB, materialize

    TC = parse_program("e(X, Y) -> T(X, Y)\\nT(X, Y) & e(Y, Z) -> T(X, Z)")
    rng = np.random.default_rng(5)
    edges = [(i, i + 1) for i in range(80)]
    edges += [tuple(e) for e in rng.integers(0, 80, (30, 2))]
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]

    ref = EngineKB(TC, B)
    st_ref = materialize(ref, mode="tg", backend="dist")

    os.environ["REPRO_CKPT_DIR"] = ckpt
    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg", backend="dist")
    s = ops.HOST_SYNC_STATS.snapshot()
    resumed = st.extra.get("resumed_rounds", 0)
    # the per-round pull accounting survives a mid-run elastic restore
    invariant = s.dist_pulls == (
        (st.rounds - resumed - s.dist_fixpoint_iters)
        + s.dist_retries + s.dist_fixpoint_pulls)
    print(json.dumps({
        "parity": kb.decode_facts() == ref.decode_facts(),
        "resumed_rounds": resumed, "rounds": st.rounds,
        "ref_rounds": st_ref.rounds,
        "resumed_from": list(st.extra.get("resumed_from", ())),
        "pulls_invariant": invariant,
    }))
""" % SRC)


def test_sigkill_then_elastic_resume_dist_subprocess(tmp_path):
    """Crash a 4-shard distributed run with kill -9, resume it on a
    2-device mesh: the checkpoint is mesh-neutral, the restoring run
    re-partitions by the exchange hash, and the closure is exact."""
    env = {"REPRO_DIST": "1", "REPRO_CKPT_DIR": str(tmp_path),
           "REPRO_CKPT_KEEP": "100",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=4"}
    r = _run(_CRASH_SCRIPT,
             {**env, "REPRO_FAULT_SPEC": "storm,crash:round=3"})
    assert r.returncode == -signal.SIGKILL, (r.returncode, r.stderr[-2000:])
    assert recovery.RecoveryManager(str(tmp_path)).tags(), \
        "crash left no durable checkpoint behind"
    loaded = recovery.RecoveryManager(str(tmp_path)).load()
    assert loaded is not None and loaded[0]["ndev"] == 4

    env.pop("XLA_FLAGS")                  # the resume script forces ndev=2
    r = _run(_DIST_RESUME_SCRIPT, env)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["parity"], out
    assert 1 <= out["resumed_rounds"] < out["rounds"]
    assert out["rounds"] == out["ref_rounds"]
    assert out["resumed_from"] == ["dist", 4]
    assert out["pulls_invariant"], out
