"""Sorted-store engine invariant: sort-pass elision, incremental merge-union,
marker propagation, and jnp-vs-Pallas kernel-dispatch parity."""
import numpy as np
import pytest

from repro.core.terms import parse_atom, parse_program
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import Relation, lex_order


def _rel(rows):
    return Relation.from_numpy(np.asarray(rows, np.int32))


def _rand(rng, n, ar, hi=50):
    return _rel(rng.integers(0, hi, (n, ar)).astype(np.int32))


def _assert_lexsorted(rel):
    rows = rel.np_rows()
    order = np.lexsort(rows.T[::-1])
    assert (order == np.arange(len(rows))).all()


# ---------------------------------------------------------------------------
# sort-call counter: no lexsort on sorted_by-marked inputs
# ---------------------------------------------------------------------------
def test_dedup_skips_sort_on_marked_input():
    rng = np.random.default_rng(0)
    s = ops.dedup(_rand(rng, 100, 2))
    assert s.is_lexsorted
    ops.SORT_STATS.reset()
    d = ops.dedup(s)
    assert ops.SORT_STATS.total_sorts() == 0
    assert ops.SORT_STATS.skipped == 1
    assert d.rows_set() == s.rows_set()


def test_antijoin_skips_haystack_sort_on_marked_input():
    rng = np.random.default_rng(1)
    hay = ops.dedup(_rand(rng, 120, 2))
    probe = _rand(rng, 40, 2)
    ops.SORT_STATS.reset()
    out = ops.antijoin(probe, hay)
    assert ops.SORT_STATS.lexsort == 0
    assert ops.SORT_STATS.skipped == 1
    assert out.rows_set() == probe.rows_set() - hay.rows_set()


def test_sm_join_skips_sort_on_primary_column_key():
    rng = np.random.default_rng(2)
    l = ops.dedup(_rand(rng, 60, 2))
    r = ops.dedup(_rand(rng, 60, 2))
    ops.SORT_STATS.reset()
    out, m = ops.sm_join(l, r, lkey=0, rkey=0)
    assert ops.SORT_STATS.total_sorts() == 0
    assert ops.SORT_STATS.skipped == 2
    la, ra = l.np_rows(), r.np_rows()
    expect = {(int(a), int(b), int(c), int(d))
              for a, b in la for c, d in ra if a == c}
    assert out.rows_set() == expect


def test_unmarked_inputs_still_sort():
    rng = np.random.default_rng(3)
    r = _rand(rng, 50, 2)
    assert r.sorted_by is None
    ops.SORT_STATS.reset()
    ops.dedup(r)
    assert ops.SORT_STATS.lexsort == 1


# ---------------------------------------------------------------------------
# marker propagation
# ---------------------------------------------------------------------------
def test_ops_preserve_or_establish_marker():
    rng = np.random.default_rng(4)
    d = ops.dedup(_rand(rng, 80, 3))
    assert d.sorted_by == lex_order(3)
    _assert_lexsorted(d)
    f = ops.filter_rows(d, const_pairs=((0, int(d.np_rows()[0, 0])),))
    assert f.sorted_by == d.sorted_by
    _assert_lexsorted(f)
    hay = ops.dedup(_rand(rng, 30, 3))
    aj = ops.antijoin(d, hay)
    assert aj.sorted_by == d.sorted_by
    _assert_lexsorted(aj)
    s = ops.sort_by(_rand(rng, 40, 2), 1)
    assert s.sorted_by == (1,)


# ---------------------------------------------------------------------------
# merge-union
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("ar", [1, 2, 3])
def test_merge_union_matches_concat_dedup(seed, ar):
    rng = np.random.default_rng(seed)
    a = ops.dedup(_rand(rng, int(rng.integers(1, 150)), ar))
    b = _rand(rng, int(rng.integers(1, 150)), ar)
    fresh = ops.antijoin(ops.dedup(b), a)
    merged = ops.merge_union(a, fresh)
    reference = ops.union(a, b, dedupe=True)
    assert merged.rows_set() == reference.rows_set()
    assert merged.count == a.count + fresh.count
    assert merged.is_lexsorted
    _assert_lexsorted(merged)


def test_merge_union_empty_sides():
    rng = np.random.default_rng(9)
    a = ops.dedup(_rand(rng, 20, 2))
    e = Relation.empty(2)
    assert ops.merge_union(a, e).rows_set() == a.rows_set()
    assert ops.merge_union(e, a).rows_set() == a.rows_set()
    assert ops.merge_union(e, Relation.empty(2)).count == 0


# ---------------------------------------------------------------------------
# materialization: store invariant + equivalence with the resort baseline
# ---------------------------------------------------------------------------
TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def _tc_base(seed=7, n=40, hi=18):
    rng = np.random.default_rng(seed)
    return [parse_atom(f"e(v{a}, v{b})")
            for a, b in rng.integers(0, hi, (n, 2))]


@pytest.mark.parametrize("mode", ["seminaive", "tg", "tg_noopt"])
def test_store_stays_lexsorted_through_materialize(mode):
    kb = EngineKB(TC, _tc_base())
    materialize(kb, mode=mode)
    for pred, rel in kb.rels.items():
        assert rel.is_lexsorted, pred
        _assert_lexsorted(rel)
        # set semantics: no duplicate rows in the store
        assert len(rel.rows_set()) == rel.count, pred


def test_sorted_store_matches_resort_baseline(monkeypatch):
    B = _tc_base(seed=11)
    kb1 = EngineKB(TC, B)
    st1 = materialize(kb1, mode="tg")
    monkeypatch.setenv("REPRO_SORTED_STORE", "0")
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg")
    assert kb1.decode_facts() == kb2.decode_facts()
    # the sorted store dedups base facts at load, so duplicate input edges
    # can only reduce the body-instantiation count
    assert st1.triggers <= st2.triggers
    assert st1.derived == st2.derived


def test_sorted_store_saves_sort_passes():
    ops.SORT_STATS.reset()
    kb = EngineKB(TC, _tc_base())
    materialize(kb, mode="tg")
    with_invariant = ops.SORT_STATS.total_sorts()
    assert ops.SORT_STATS.skipped > 0
    assert ops.SORT_STATS.merges > 0
    import os
    os.environ["REPRO_SORTED_STORE"] = "0"
    try:
        ops.SORT_STATS.reset()
        kb = EngineKB(TC, _tc_base())
        materialize(kb, mode="tg")
        without = ops.SORT_STATS.total_sorts()
    finally:
        del os.environ["REPRO_SORTED_STORE"]
    assert with_invariant < without


# ---------------------------------------------------------------------------
# kernel dispatch parity: jnp reference vs Pallas (interpret) over randomized
# relations
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(3))
def test_pallas_dispatch_parity(monkeypatch, seed):
    rng = np.random.default_rng(seed)
    a = _rand(rng, int(rng.integers(10, 120)), 2, hi=30)
    hay2 = _rand(rng, int(rng.integers(5, 60)), 2, hi=30)
    hay1 = _rand(rng, int(rng.integers(5, 60)), 1, hi=30)
    l = _rand(rng, 64, 2, hi=12)
    r = _rand(rng, 48, 2, hi=12)

    def snapshot():
        d = ops.dedup(a)
        aj2 = ops.antijoin(a, ops.dedup(hay2))
        aj1 = ops.antijoin(a, ops.dedup(hay1), cols=(1,))
        j, m = ops.sm_join(l, r, lkey=1, rkey=0)
        return (d.rows_set(), aj2.rows_set(), aj1.rows_set(),
                j.rows_set(), m)

    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    ref = snapshot()
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    got = snapshot()
    assert got == ref


def test_pallas_materialize_parity(monkeypatch):
    B = _tc_base(seed=13)
    monkeypatch.setenv("REPRO_USE_PALLAS", "0")
    kb1 = EngineKB(TC, B)
    materialize(kb1, mode="tg")
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    kb2 = EngineKB(TC, B)
    materialize(kb2, mode="tg")
    assert kb1.decode_facts() == kb2.decode_facts()
