"""TGmat (Alg. 2), minDatalog (Def. 19), Def. 23 strategy, EG-rewriting."""
import pytest

from repro.core.chase import chase
from repro.core.eg import EG
from repro.core.rewrite import eg_rewriting, rewriting_contained
from repro.core.terms import Atom, Var, parse_atom, parse_program, parse_rule
from repro.core.tg_datalog import tgmat


TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def _tc_base(n=6, cyc=True):
    B = [parse_atom(f"e(v{i}, v{i+1})") for i in range(n)]
    if cyc:
        B.append(parse_atom(f"e(v{n}, v0)"))
    return B


@pytest.mark.parametrize("use_min,use_ruleexec", [
    (False, False), (True, False), (True, True)])
def test_tgmat_equals_chase_tc(use_min, use_ruleexec):
    B = _tc_base()
    ch = chase(TC, B)
    I, eg, st = tgmat(TC, B, use_min=use_min, use_ruleexec=use_ruleexec)
    assert set(I.facts) == set(ch.facts)


def test_tgmat_example22_trigger_reduction():
    """Def. 23 antijoin: the second rule's instantiations shrink (Ex. 22)."""
    P = parse_program("""
        a(X) & b(X) -> A(X)
        ap(X) & bp(X) -> A(X)
    """)
    B = ([parse_atom(f"a(x{i})") for i in range(100)]
         + [parse_atom(f"b(x{i})") for i in range(100)]
         + [parse_atom(f"ap(x{i})") for i in range(51)]
         + [parse_atom(f"bp(x{i})") for i in range(50)])
    ch = chase(P, B)
    _, _, no_opt = tgmat(P, B, use_min=False, use_ruleexec=False)
    _, _, with_r = tgmat(P, B, use_min=True, use_ruleexec=True)
    assert with_r["triggers"] < no_opt["triggers"] == ch.triggers


def test_tgmat_multi_rule_redundancy():
    """Cross-rule redundant derivations (the SNE blind spot, Example 2)."""
    P = parse_program("""
        r(X, Y) -> R(X, Y)
        R(X, Y) -> S(Y, X)
        S(Y, X) -> R(X, Y)
    """)
    B = [parse_atom(f"r(a{i}, b{i})") for i in range(20)]
    ch = chase(P, B)
    I, eg, st = tgmat(P, B)
    assert set(I.facts) == set(ch.facts)
    assert st["triggers"] < ch.triggers


def test_example44_compatible_combinations():
    P = parse_program("""
        a(X) -> A(X)
        r(X, Y) -> R(X, Y)
        R(X, Y) & A(Y) -> A(X)
        R(X, Y) & R(Y, Z) -> A(X)
    """)
    B = [parse_atom("a(n2)"), parse_atom("r(n1, n2)"), parse_atom("r(n0, n1)")]
    ch = chase(P, B)
    I, eg, st = tgmat(P, B)
    assert set(I.facts) == set(ch.facts)


def test_eg_rewriting_example43():
    """Example 43: rew(u2) == Q(Y2,Z2) <- r(Y2, Z2, Z1)."""
    P = parse_program("""
        r(X1, Y1, Z1) -> T(X1, X1, Y1)
        T(X2, Y2, Z2) -> R(Y2, Z2)
    """)
    eg = EG(P)
    u1 = eg.add_node(P.rules[0])
    u2 = eg.add_node(P.rules[1])
    eg.add_edge(u1, 0, u2)
    q = eg_rewriting(eg, u2)
    assert len(q.body) == 1
    (b,) = q.body
    assert b.pred == "r"
    # head args equal the first two args of the body atom
    assert q.head_args == (b.args[0], b.args[1])


def test_rewriting_containment_same_node():
    eg = EG(TC.normalize())
    ext = [r for r in TC.normalize().extensional_rules()]
    v1 = eg.add_node(ext[0])
    v2 = eg.add_node(ext[0])
    q1, q2 = eg_rewriting(eg, v1), eg_rewriting(eg, v2)
    assert rewriting_contained(q1, q2) and rewriting_contained(q2, q1)


def test_min_datalog_prunes_duplicate_paths():
    """Two rules deriving the same predicate from the same EDB — one node
    per level suffices after minDatalog."""
    P = parse_program("""
        e(X, Y) -> A(X, Y)
        e(X, Y) -> B(X, Y)
        A(X, Y) -> C(X, Y)
        B(X, Y) -> C(X, Y)
    """)
    B = [parse_atom("e(u, v)")]
    ch = chase(P, B)
    I, eg, st_min = tgmat(P, B, use_min=True)
    I2, eg2, st_nomin = tgmat(P, B, use_min=False)
    assert set(I.facts) == set(I2.facts) == set(ch.facts)
    assert st_min["triggers"] <= st_nomin["triggers"]
