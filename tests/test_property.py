"""Property-based tests (hypothesis): system invariants.

* TGmat(P, B) == Ch(P, B) for random Datalog programs (Thm. 24)
* tglinear is a TG for random linear FES programs (Thm. 10) and minLinear
  preserves the TG property (Thm. 15)
* engine materialization == symbolic chase on random instances
* engine relational ops vs numpy oracles
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.chase import chase
from repro.core.eg import is_tg_for
from repro.core.terms import Atom, Program, Rule, Var, parse_atom
from repro.core.tg_datalog import tgmat
from repro.core.tg_linear import min_linear, tglinear
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import Relation

X, Y, Z = Var("X"), Var("Y"), Var("Z")
SETTINGS = dict(deadline=None, max_examples=25,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# random Datalog programs
# ---------------------------------------------------------------------------
@st.composite
def datalog_program(draw):
    edb = ["e", "f"]
    idb = ["P", "Q", "R"]
    n_rules = draw(st.integers(2, 5))
    rules = []
    # extensional seeds so IDBs are reachable
    rules.append(Rule((Atom("e", (X, Y)),), Atom(draw(st.sampled_from(idb)),
                                                 (X, Y)), "seed"))
    for i in range(n_rules):
        n_body = draw(st.integers(1, 2))
        body = []
        vars_pool = [X, Y, Z]
        for _ in range(n_body):
            p = draw(st.sampled_from(edb + idb))
            a1 = draw(st.sampled_from(vars_pool))
            a2 = draw(st.sampled_from(vars_pool))
            body.append(Atom(p, (a1, a2)))
        head_vars = [v for a in body for v in a.args]
        h1 = draw(st.sampled_from(head_vars))
        h2 = draw(st.sampled_from(head_vars))
        rules.append(Rule(tuple(body), Atom(draw(st.sampled_from(idb)),
                                            (h1, h2)), f"g{i}"))
    return Program(rules)


@st.composite
def base_instance(draw):
    n = draw(st.integers(1, 8))
    consts = [f"c{i}" for i in range(draw(st.integers(2, 5)))]
    facts = set()
    for _ in range(n):
        p = draw(st.sampled_from(["e", "f"]))
        facts.add(Atom(p, (draw(st.sampled_from(consts)),
                           draw(st.sampled_from(consts)))))
    return list(facts)


@given(datalog_program(), base_instance())
@settings(**SETTINGS)
def test_tgmat_equals_chase_random(P, B):
    ch = chase(P, B, max_rounds=50)
    if not ch.terminated:
        return
    I, _, _ = tgmat(P, B, max_rounds=50)
    assert set(I.facts) == set(ch.facts)


@given(datalog_program(), base_instance())
@settings(**SETTINGS)
def test_engine_equals_chase_random(P, B):
    ch = chase(P, B, max_rounds=50)
    if not ch.terminated:
        return
    kb = EngineKB(P, B)
    materialize(kb, mode="tg", max_rounds=50)
    assert kb.decode_facts() == set(ch.facts) | set(B)


# ---------------------------------------------------------------------------
# random linear programs (Datalog fragment => FES)
# ---------------------------------------------------------------------------
@st.composite
def linear_program(draw):
    idb = ["P", "Q", "R"]
    rules = [Rule((Atom("e", (X, Y)),),
                  Atom(draw(st.sampled_from(idb)),
                       draw(st.sampled_from([(X, Y), (Y, X), (X, X)]))),
                  "seed")]
    for i in range(draw(st.integers(1, 4))):
        src = draw(st.sampled_from(idb))
        dst = draw(st.sampled_from(idb))
        b_args = draw(st.sampled_from([(X, Y), (Y, X), (X, X)]))
        h_args = draw(st.sampled_from([(X, Y), (Y, X), (X, X), (Y, Y)]))
        used = {t for t in h_args}
        if not used <= {t for t in b_args}:
            continue
        rules.append(Rule((Atom(src, b_args),), Atom(dst, h_args), f"g{i}"))
    return Program(rules)


@given(linear_program(), base_instance())
@settings(**SETTINGS)
def test_tglinear_is_tg_random(P, B):
    B = [f for f in B if f.pred == "e"]
    if not B:
        return
    G = tglinear(P)
    assert is_tg_for(G, P, B)
    G2 = min_linear(G)
    assert is_tg_for(G2, P, B)


# ---------------------------------------------------------------------------
# engine ops invariants
# ---------------------------------------------------------------------------
@given(st.lists(st.tuples(st.integers(0, 15), st.integers(0, 15)),
                min_size=1, max_size=60))
@settings(**SETTINGS)
def test_dedup_oracle(rows):
    r = Relation.from_numpy(np.asarray(rows, np.int32))
    d = ops.dedup(r)
    assert d.rows_set() == set(rows)
    assert d.count == len(set(rows))


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
                min_size=1, max_size=40),
       st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
                min_size=1, max_size=40))
@settings(**SETTINGS)
def test_join_oracle(lrows, rrows):
    l = Relation.from_numpy(np.asarray(lrows, np.int32))
    r = Relation.from_numpy(np.asarray(rrows, np.int32))
    out, m = ops.sm_join(l, r, lkey=1, rkey=0)
    expect = [(a, b, c, d) for a, b in lrows for c, d in rrows if b == c]
    assert m == len(expect)
    assert out.rows_set() == set(expect)


@given(st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
                min_size=1, max_size=40),
       st.lists(st.tuples(st.integers(0, 9), st.integers(0, 9)),
                min_size=1, max_size=40))
@settings(**SETTINGS)
def test_antijoin_oracle(rows, hay):
    r = Relation.from_numpy(np.asarray(rows, np.int32))
    h = Relation.from_numpy(np.asarray(hay, np.int32))
    a = ops.antijoin(r, h)
    assert a.rows_set() == {t for t in rows if t not in set(hay)}
