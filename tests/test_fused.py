"""Fused round executor: host-sync accounting, capacity-overflow retry,
linear-tail while_loop behavior, and store-invariant preservation."""
import numpy as np
import pytest

from repro.core.terms import parse_atom, parse_program
from repro.engine import fused, ops
from repro.engine.materialize import EngineKB, materialize
from repro.engine.relation import lex_order

TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def _chain(n, extra=0, seed=0):
    rng = np.random.default_rng(seed)
    edges = [(i, i + 1) for i in range(n)]
    edges += [tuple(e) for e in rng.integers(0, n, (extra, 2))]
    return [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


@pytest.mark.parametrize("mode", ["tg", "tg_noopt"])
def test_fused_matches_two_phase(mode, monkeypatch):
    B = _chain(24, extra=16, seed=3)
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    kb1 = EngineKB(TC, B)
    st1 = materialize(kb1, mode=mode)
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode=mode)
    assert st2.extra.get("fused") is True
    assert kb1.decode_facts() == kb2.decode_facts()
    assert (st1.rounds, st1.triggers, st1.derived) == \
        (st2.rounds, st2.triggers, st2.derived)


def test_fused_host_sync_reduction(monkeypatch):
    """The deep-chain fixpoint must collapse hundreds of per-primitive
    host pulls into a handful of per-round / per-fixpoint pulls."""
    B = _chain(48)
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    ops.HOST_SYNC_STATS.reset()
    kb1 = EngineKB(TC, B)
    st1 = materialize(kb1, mode="tg")
    unfused_pulls = ops.HOST_SYNC_STATS.total()

    monkeypatch.setenv("REPRO_FUSED", "1")
    ops.HOST_SYNC_STATS.reset()
    kb2 = EngineKB(TC, B)
    st2 = materialize(kb2, mode="tg")
    fused_pulls = ops.HOST_SYNC_STATS.total()

    assert kb1.decode_facts() == kb2.decode_facts()
    assert st1.rounds == st2.rounds > 40
    # the whole linear tail ran inside lax.while_loop: far fewer pulls than
    # rounds, and >=5x below the two-phase executor
    assert fused_pulls < st2.rounds
    assert fused_pulls * 5 <= unfused_pulls


def test_fused_overflow_retry_exactly_once(monkeypatch):
    """A join whose output exceeds the planned capacity triggers exactly one
    recompile-and-retry (capacity doubling) and identical facts."""
    B = _chain(60)
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    kb_ref = EngineKB(TC, B)
    materialize(kb_ref, mode="tg")

    monkeypatch.setenv("REPRO_FUSED", "1")
    kb_warm = EngineKB(TC, B)
    materialize(kb_warm, mode="tg")   # converge store/delta capacity memo

    # plant a join plan one doubling short of what this instance needs: the
    # chain's biggest join emits 59 rows, so a 32-row bucket overflows once
    def small_join_cap(self, plan, idx):
        key = (plan.key, idx)
        if key not in self.join:
            self.join[key] = 32
        return self.join[key]
    monkeypatch.setattr(fused._Caps, "join_cap", small_join_cap)

    ops.HOST_SYNC_STATS.reset()
    kb = EngineKB(TC, B)
    st = materialize(kb, mode="tg")
    assert st.extra.get("fused") is True
    assert ops.HOST_SYNC_STATS.fused_retries == 1
    assert kb.decode_facts() == kb_ref.decode_facts()


def test_fused_store_invariant(monkeypatch):
    """Fused stores come back lexsorted, compacted, and set-semantic."""
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = EngineKB(TC, _chain(20, extra=12, seed=5))
    materialize(kb, mode="tg")
    for pred, rel in kb.rels.items():
        assert rel.sorted_by == lex_order(rel.arity), pred
        rows = rel.np_rows()
        order = np.lexsort(rows.T[::-1])
        assert (order == np.arange(len(rows))).all(), pred
        assert len(rel.rows_set()) == rel.count, pred


def test_fused_capacity_memo_warm_start(monkeypatch):
    """A warmed program plans right first try: zero retries on rerun."""
    B = _chain(30, extra=8, seed=9)
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = EngineKB(TC, B)
    materialize(kb, mode="tg")
    ops.HOST_SYNC_STATS.reset()
    kb2 = EngineKB(TC, B)
    materialize(kb2, mode="tg")
    assert ops.HOST_SYNC_STATS.fused_retries == 0


def test_fused_falls_back_outside_fragment(monkeypatch):
    """Existential rules are outside the fused fragment: same facts, no
    fused flag."""
    P = parse_program("""
        p(X, Y) -> Q(X, Y)
        Q(X, Y) & Q(Y, Z) -> exists W. Q(Z, W)
    """)
    B = [parse_atom("p(a, b)"), parse_atom("p(b, c)")]
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    kb1 = EngineKB(P, B)
    materialize(kb1, mode="tg", max_rounds=5)
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb2 = EngineKB(P, B)
    st2 = materialize(kb2, mode="tg", max_rounds=5)
    assert st2.extra.get("fused") is None
    assert kb1.decode_facts() == kb2.decode_facts()


def test_seminaive_never_fused(monkeypatch):
    """Per-rule filtering semantics stay on the two-phase path."""
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = EngineKB(TC, _chain(10))
    st = materialize(kb, mode="seminaive")
    assert st.extra.get("fused") is None
