"""Cross-engine differential suite — the oracle gating the fused refactor.

Asserts ``decode_facts()`` parity across every execution tier on randomly
generated programs + bases:

* symbolic ``chase`` (ground truth),
* two-phase engine: ``seminaive`` / ``tg`` / ``tg_noopt``,
* ``tg_linear`` over a precomputed ``tglinear``/``minLinear`` EG,
* the fused round executor (``REPRO_FUSED=1``),
* the distributed shard_map executor (``backend="dist"``) — in-process over
  however many local devices exist (1 in plain runs; the CI multi-device
  leg forces 8), and in forced 4- and 8-device subprocesses, both with and
  without capacity-overflow retries, plus a forced tail-overflow
  mid-fixpoint leg exercising the while_loop overflow carry,

under both kernel dispatch paths (``REPRO_USE_PALLAS=0/1``).

Programs are drawn two ways: seeded numpy generators that always run
(deterministic everywhere), plus hypothesis-driven cases when hypothesis is
installed (the CI dev extra).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.chase import chase
from repro.core.terms import Atom, Program, Rule, Var, parse_atom, parse_program
from repro.core.tg_linear import min_linear, tglinear
from repro.data.kb_sources import LUBM_L, RHO_DF, rho_df_facts
from repro.engine import ops
from repro.engine.materialize import EngineKB, materialize

X, Y, Z = Var("X"), Var("Y"), Var("Z")
MAX_ROUNDS = 60


# ---------------------------------------------------------------------------
# seeded generators (mirror the hypothesis strategies in test_property)
# ---------------------------------------------------------------------------
def random_datalog(rng) -> Program:
    edb, idb = ["e", "f"], ["P", "Q", "R"]
    pool = [X, Y, Z]
    rules = [Rule((Atom("e", (X, Y)),),
                  Atom(str(rng.choice(idb)), (X, Y)), "seed")]
    for i in range(int(rng.integers(2, 6))):
        body = []
        for _ in range(int(rng.integers(1, 3))):
            p = str(rng.choice(edb + idb))
            body.append(Atom(p, (pool[rng.integers(0, 3)],
                                 pool[rng.integers(0, 3)])))
        head_vars = [v for a in body for v in a.args]
        h1 = head_vars[rng.integers(0, len(head_vars))]
        h2 = head_vars[rng.integers(0, len(head_vars))]
        rules.append(Rule(tuple(body),
                          Atom(str(rng.choice(idb)), (h1, h2)), f"g{i}"))
    return Program(rules)


def random_linear(rng) -> Program:
    idb = ["P", "Q", "R"]
    arg_pool = [(X, Y), (Y, X), (X, X)]
    head_pool = arg_pool + [(Y, Y)]
    rules = [Rule((Atom("e", (X, Y)),),
                  Atom(str(rng.choice(idb)), arg_pool[rng.integers(0, 3)]),
                  "seed")]
    for i in range(int(rng.integers(1, 5))):
        b_args = arg_pool[rng.integers(0, 3)]
        h_args = head_pool[rng.integers(0, 4)]
        if not {t for t in h_args} <= {t for t in b_args}:
            continue
        rules.append(Rule((Atom(str(rng.choice(idb)), b_args),),
                          Atom(str(rng.choice(idb)), h_args), f"g{i}"))
    return Program(rules)


def random_base(rng, preds=("e", "f")):
    consts = [f"c{i}" for i in range(int(rng.integers(2, 5)))]
    facts = set()
    for _ in range(int(rng.integers(1, 9))):
        facts.add(Atom(str(rng.choice(list(preds))),
                       (str(rng.choice(consts)), str(rng.choice(consts)))))
    return sorted(facts, key=repr)


# ---------------------------------------------------------------------------
# the oracle
# ---------------------------------------------------------------------------
def assert_all_engines_agree(P, B, monkeypatch, linear: bool = False):
    """Every engine tier × flag combination must reproduce the chase."""
    ch = chase(P, B, max_rounds=MAX_ROUNDS)
    if not ch.terminated:
        return
    expected = set(ch.facts) | set(B)
    eg = min_linear(tglinear(P)) if linear else None
    for pallas in ("0", "1"):
        monkeypatch.setenv("REPRO_USE_PALLAS", pallas)
        for fused in ("0", "1"):
            monkeypatch.setenv("REPRO_FUSED", fused)
            for mode in ("seminaive", "tg", "tg_noopt"):
                kb = EngineKB(P, B)
                materialize(kb, mode=mode, max_rounds=MAX_ROUNDS)
                assert kb.decode_facts() == expected, (
                    f"mode={mode} pallas={pallas} fused={fused}\n{P}")
        if eg is not None:       # tg_linear has no fused variant
            for cleaning in (True, False):
                kb = EngineKB(P, B)
                materialize(kb, mode="tg_linear", tg_eg=eg,
                            cleaning=cleaning)
                assert kb.decode_facts() == expected, (
                    f"tg_linear cleaning={cleaning} pallas={pallas}\n{P}")


@pytest.mark.parametrize("seed", range(6))
def test_differential_datalog(seed, monkeypatch):
    rng = np.random.default_rng(1000 + seed)
    P = random_datalog(rng)
    B = random_base(rng)
    assert_all_engines_agree(P, B, monkeypatch)


@pytest.mark.parametrize("seed", range(4))
def test_differential_linear(seed, monkeypatch):
    rng = np.random.default_rng(2000 + seed)
    P = random_linear(rng)
    B = [f for f in random_base(rng, preds=("e",))]
    if not B:
        return
    assert_all_engines_agree(P, B, monkeypatch, linear=True)


def test_differential_transitive_closure(monkeypatch):
    """Deep fixpoint (the fused while_loop path) on both TC orientations."""
    rng = np.random.default_rng(7)
    edges = ([(i, i + 1) for i in range(20)]
             + [tuple(e) for e in rng.integers(0, 20, (10, 2))])
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]
    for text in ("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)",
                 "e(X, Y) -> T(Y, X)\nT(Y, X) & e(Y, Z) -> T(Z, X)"):
        assert_all_engines_agree(parse_program(text), B, monkeypatch)


# ---------------------------------------------------------------------------
# distributed backend: decode_facts parity vs chase / seminaive / tg / fused
# on LUBM-L, rho-df and TC (ndev = local devices in-process; forced 4-device
# mesh in a subprocess)
# ---------------------------------------------------------------------------
TC_PROGRAM = "e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)"


def _tc_base(n=16, chords=((9, 3), (5, 12))):
    edges = [(i, i + 1) for i in range(n)] + list(chords)
    return [parse_atom(f"e(v{a}, v{b})") for a, b in edges]


def _mini_lubm_base():
    """Trimmed university instance, small enough for the symbolic chase."""
    A = Atom
    return [A("subOrg", ("d0", "u0")), A("subOrg", ("g0", "d0")),
            A("subOrg", ("d1", "u0")), A("subOrg", ("g1", "g0")),
            A("fullProf", ("p0", "d0")), A("assocProf", ("p1", "d0")),
            A("assistProf", ("p2", "d1")), A("lecturer", ("l0", "d1")),
            A("headOf", ("p0", "d0")),
            A("gradStudent", ("s0", "d0")), A("ugStudent", ("s1", "d1")),
            A("teaches", ("p0", "c0")), A("teaches", ("p1", "c1")),
            A("takes", ("s0", "c0")), A("takes", ("s1", "c0")),
            A("advisor", ("s0", "p0")), A("publication", ("b0", "p0"))]


def _mini_rho_df_base():
    return rho_df_facts(n_classes=6, n_props=4, n_instances=8)


def assert_dist_agrees(P, B, monkeypatch, max_rounds=MAX_ROUNDS):
    """chase == seminaive == tg == fused == distributed on one instance."""
    ch = chase(P, B, max_rounds=max_rounds)
    assert ch.terminated
    expected = set(ch.facts) | set(B)
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    monkeypatch.delenv("REPRO_DIST", raising=False)
    for mode in ("seminaive", "tg"):
        kb = EngineKB(P, B)
        materialize(kb, mode=mode, max_rounds=max_rounds)
        assert kb.decode_facts() == expected, mode
    monkeypatch.setenv("REPRO_FUSED", "1")
    kb = EngineKB(P, B)
    materialize(kb, mode="tg", max_rounds=max_rounds)
    assert kb.decode_facts() == expected, "fused"
    monkeypatch.delenv("REPRO_FUSED", raising=False)
    kbd = EngineKB(P, B)
    st = materialize(kbd, mode="tg", max_rounds=max_rounds, backend="dist")
    assert st.extra.get("dist") is True
    assert kbd.decode_facts() == expected, "dist"
    return st


def test_differential_dist_tc(monkeypatch):
    assert_dist_agrees(parse_program(TC_PROGRAM), _tc_base(), monkeypatch)


def test_differential_dist_lubm(monkeypatch):
    assert_dist_agrees(LUBM_L, _mini_lubm_base(), monkeypatch)


def test_differential_dist_rhodf(monkeypatch):
    assert_dist_agrees(RHO_DF, _mini_rho_df_base(), monkeypatch)


def test_differential_dist_warm_no_retries(monkeypatch):
    """Second run of a warmed program plans right first try: parity holds
    with ZERO overflow retries (the 'without retries' leg)."""
    P, B = parse_program(TC_PROGRAM), _tc_base()
    assert_dist_agrees(P, B, monkeypatch)
    ops.HOST_SYNC_STATS.reset()
    st = assert_dist_agrees(P, B, monkeypatch)
    s = ops.HOST_SYNC_STATS
    assert s.dist_retries == 0
    # every pull accounted for exactly once; the linear tail ran
    # on-device, so pulls collapse well below the round count
    assert s.dist_pulls == (st.rounds - s.dist_fixpoint_iters) \
        + s.dist_retries + s.dist_fixpoint_pulls
    assert s.dist_fixpoint_iters > 0
    assert s.dist_pulls < st.rounds


def test_differential_dist_tail_overflow_mid_fixpoint(monkeypatch):
    """Forced tail overflow MID-fixpoint: an 8-row fixpoint tail fills
    every few while_loop iterations, so the program exits early, the host
    folds + doubles + resumes, and parity must still hold (the overflow
    flags riding the loop carry are load-bearing here)."""
    from repro.engine import plan
    monkeypatch.setattr(plan, "_CAP_MEMO", {})

    def tiny_tail(self, pred):
        if pred not in self.tail:
            self.tail[pred] = 8
        return self.tail[pred]
    monkeypatch.setattr(plan._Caps, "tail_cap", tiny_tail)
    ops.HOST_SYNC_STATS.reset()
    assert_dist_agrees(parse_program(TC_PROGRAM), _tc_base(), monkeypatch)
    # the phase could not finish in one program invocation
    assert ops.HOST_SYNC_STATS.dist_fixpoint_pulls >= 3


def test_differential_dist_forced_retries(monkeypatch):
    """Parity must survive capacity-overflow retries: plant tiny exchange
    buckets and 1-row delta buffers so early rounds overflow at any shard
    count and the driver's double-and-retry loop has to converge (the
    'with retries' leg)."""
    from repro.engine import plan
    monkeypatch.setattr(plan, "_CAP_MEMO", {})

    def tiny_bucket(self, key):
        if key not in self.bucket:
            self.bucket[key] = 8
        return self.bucket[key]

    def tiny_delta(self, pred):
        if pred not in self.delta:
            self.delta[pred] = 1
        return self.delta[pred]
    monkeypatch.setattr(plan._Caps, "bucket_cap", tiny_bucket)
    monkeypatch.setattr(plan._Caps, "delta_cap", tiny_delta)
    ops.HOST_SYNC_STATS.reset()
    assert_dist_agrees(parse_program(TC_PROGRAM), _tc_base(), monkeypatch)
    assert ops.HOST_SYNC_STATS.dist_retries >= 1


_DIST_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = \\
        "--xla_force_host_platform_device_count=%d"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import sys, json
    sys.path.insert(0, %r)
    from repro.core.terms import parse_atom, parse_program
    from repro.data.kb_sources import LUBM_L, RHO_DF, rho_df_facts
    from repro.engine import ops
    from repro.engine.materialize import EngineKB, materialize

    TC = parse_program(%r)
    B_tc = [parse_atom(f"e(v{i}, v{i+1})") for i in range(16)] + \\
        [parse_atom("e(v9, v3)"), parse_atom("e(v5, v12)")]
    lubm_base = [parse_atom(s) for s in %r]
    scens = [("tc", TC, B_tc), ("lubm", LUBM_L, lubm_base),
             ("rhodf", RHO_DF, rho_df_facts(n_classes=6, n_props=4,
                                            n_instances=8))]
    out = []
    for name, P, B in scens:
        kb1 = EngineKB(P, B)
        materialize(kb1, mode="tg")
        ops.HOST_SYNC_STATS.reset()
        kb2 = EngineKB(P, B)
        st = materialize(kb2, mode="tg", backend="dist")
        s = ops.HOST_SYNC_STATS
        out.append({"name": name, "ndev": st.extra["ndev"],
                    "parity": kb1.decode_facts() == kb2.decode_facts(),
                    "rounds": st.rounds, "pulls": s.dist_pulls,
                    "retries": s.dist_retries,
                    "fix_pulls": s.dist_fixpoint_pulls,
                    "fix_iters": s.dist_fixpoint_iters})
    # forced-overflow leg: tiny exchange buckets + 1-row delta buffers ->
    # retries must fire at any shard count and converge
    from repro.engine import plan
    plan._CAP_MEMO.clear()
    def tiny_bucket(self, key):
        if key not in self.bucket:
            self.bucket[key] = 8
        return self.bucket[key]
    def tiny_delta(self, pred):
        if pred not in self.delta:
            self.delta[pred] = 1
        return self.delta[pred]
    plan._Caps.bucket_cap = tiny_bucket
    plan._Caps.delta_cap = tiny_delta
    kb1 = EngineKB(TC, B_tc); materialize(kb1, mode="tg")
    ops.HOST_SYNC_STATS.reset()
    kb2 = EngineKB(TC, B_tc)
    st = materialize(kb2, mode="tg", backend="dist")
    s = ops.HOST_SYNC_STATS
    out.append({"name": "tc_retry", "ndev": st.extra["ndev"],
                "parity": kb1.decode_facts() == kb2.decode_facts(),
                "rounds": st.rounds, "pulls": s.dist_pulls,
                "retries": s.dist_retries,
                "fix_pulls": s.dist_fixpoint_pulls,
                "fix_iters": s.dist_fixpoint_iters})
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.parametrize("ndev", [4, 8])
def test_differential_dist_ndev_subprocess(ndev):
    """LUBM-L / rho-df / TC parity on forced 4- and 8-shard meshes, with
    and without overflow retries (subprocess: the forced device count must
    not leak into this process)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    lubm_strs = [repr(a) for a in _mini_lubm_base()]
    script = _DIST_SUBPROC % (ndev, src, TC_PROGRAM, lubm_strs)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines() if ln.startswith("RESULT ")][-1]
    results = json.loads(line[len("RESULT "):])
    assert len(results) == 4
    for rec in results:
        assert rec["ndev"] == ndev, rec
        assert rec["parity"], rec
        # every scalar pull accounted for once: host-stepped rounds +
        # host-stepped retries + fixpoint-program exits — ndev-independent
        assert rec["pulls"] == (rec["rounds"] - rec["fix_iters"]) \
            + rec["retries"] + rec["fix_pulls"], rec
    assert results[-1]["name"] == "tc_retry" and results[-1]["retries"] >= 1


# ---------------------------------------------------------------------------
# hypothesis-driven cases (runs when the CI dev extra is installed)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(deadline=None, max_examples=10,
                    suppress_health_check=[HealthCheck.too_slow])

    @st.composite
    def seeded_case(draw):
        seed = draw(st.integers(0, 2 ** 16))
        return np.random.default_rng(seed)

    @given(seeded_case())
    @settings(**SETTINGS)
    def test_differential_datalog_hypothesis(rng):
        P = random_datalog(rng)
        B = random_base(rng)
        with pytest.MonkeyPatch.context() as mp:
            assert_all_engines_agree(P, B, mp)

    @given(seeded_case())
    @settings(**SETTINGS)
    def test_differential_linear_hypothesis(rng):
        P = random_linear(rng)
        B = random_base(rng, preds=("e",))
        if not B:
            return
        with pytest.MonkeyPatch.context() as mp:
            assert_all_engines_agree(P, B, mp, linear=True)


# ---------------------------------------------------------------------------
# incremental maintenance (PR 8): interleaved insert/delete batches must
# track the from-scratch materialization of the evolving base at every step
# ---------------------------------------------------------------------------
def _update_schedule(P, B, rng, steps=4):
    """Random (insertions, deletions) batches + the base set after each."""
    consts = [f"d{i}" for i in range(4)]
    schedule, bases, cur = [], [], set(B)
    for _ in range(steps):
        ins = {Atom(str(rng.choice(["e", "f"])),
                    (str(rng.choice(consts)), str(rng.choice(consts))))
               for _ in range(int(rng.integers(1, 4)))}
        dels = set()
        if cur:
            pool = sorted(cur, key=repr)
            for i in rng.choice(len(pool),
                                size=min(len(pool), int(rng.integers(0, 3))),
                                replace=False):
                dels.add(pool[i])
        cur = (cur - dels) | ins
        schedule.append((sorted(ins, key=repr), sorted(dels, key=repr)))
        bases.append(sorted(cur, key=repr))
    return schedule, bases


def assert_incremental_tracks_scratch(P, B, rng, monkeypatch, steps=4):
    schedule, bases = _update_schedule(P, B, rng, steps=steps)
    # engine-independent expected facts per step (two-phase reference)
    monkeypatch.setenv("REPRO_FUSED", "0")
    expected = []
    for nb in bases:
        ref = EngineKB(P, nb)
        materialize(ref, max_rounds=MAX_ROUNDS)
        expected.append(ref.decode_facts())
    for pallas in ("0", "1"):
        monkeypatch.setenv("REPRO_USE_PALLAS", pallas)
        for fused in ("0", "1"):
            monkeypatch.setenv("REPRO_FUSED", fused)
            kb = EngineKB(P, B)
            materialize(kb, max_rounds=MAX_ROUNDS)
            for step, (ins, dels) in enumerate(schedule):
                kb.materialize_delta(insertions=ins, deletions=dels,
                                     max_rounds=MAX_ROUNDS)
                assert kb.decode_facts() == expected[step], (
                    f"step={step} pallas={pallas} fused={fused}\n{P}")


@pytest.mark.parametrize("seed", range(3))
def test_differential_incremental_interleaved(seed, monkeypatch):
    rng = np.random.default_rng(3000 + seed)
    P = random_datalog(rng)
    B = random_base(rng)
    assert_incremental_tracks_scratch(P, B, rng, monkeypatch)


def test_differential_incremental_tc(monkeypatch):
    """Deep recursive fixpoint under updates (fused while_loop delta path)."""
    rng = np.random.default_rng(31)
    P = parse_program(TC_PROGRAM)
    B = [parse_atom(f"e(v{i}, v{i + 1})") for i in range(12)]
    schedule = [
        ([parse_atom("e(v12, v13)")], []),               # extend the chain
        ([parse_atom("e(w0, w1)")], [parse_atom("e(v5, v6)")]),  # split it
        ([], [parse_atom("T(v0, v1)")]),                 # rederivable delete
        ([parse_atom("e(v5, v6)")], [parse_atom("e(w0, w1)")]),  # re-join
    ]
    cur, bases = set(B), []
    for ins, dels in schedule:
        cur = (cur - set(dels)) | set(ins)
        bases.append(sorted(cur, key=repr))
    monkeypatch.setenv("REPRO_FUSED", "0")
    expected = []
    for nb in bases:
        ref = EngineKB(P, nb)
        materialize(ref, max_rounds=MAX_ROUNDS)
        expected.append(ref.decode_facts())
    for fused in ("0", "1"):
        monkeypatch.setenv("REPRO_FUSED", fused)
        kb = EngineKB(P, B)
        materialize(kb, max_rounds=MAX_ROUNDS)
        for step, (ins, dels) in enumerate(schedule):
            kb.materialize_delta(insertions=ins, deletions=dels,
                                 max_rounds=MAX_ROUNDS)
            assert kb.decode_facts() == expected[step], (
                f"step={step} fused={fused}")
