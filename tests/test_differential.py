"""Cross-engine differential suite — the oracle gating the fused refactor.

Asserts ``decode_facts()`` parity across every execution tier on randomly
generated programs + bases:

* symbolic ``chase`` (ground truth),
* two-phase engine: ``seminaive`` / ``tg`` / ``tg_noopt``,
* ``tg_linear`` over a precomputed ``tglinear``/``minLinear`` EG,
* the fused round executor (``REPRO_FUSED=1``),

under both kernel dispatch paths (``REPRO_USE_PALLAS=0/1``).

Programs are drawn two ways: seeded numpy generators that always run
(deterministic everywhere), plus hypothesis-driven cases when hypothesis is
installed (the CI dev extra).
"""
import numpy as np
import pytest

from repro.core.chase import chase
from repro.core.terms import Atom, Program, Rule, Var
from repro.core.tg_linear import min_linear, tglinear
from repro.engine.materialize import EngineKB, materialize

X, Y, Z = Var("X"), Var("Y"), Var("Z")
MAX_ROUNDS = 60


# ---------------------------------------------------------------------------
# seeded generators (mirror the hypothesis strategies in test_property)
# ---------------------------------------------------------------------------
def random_datalog(rng) -> Program:
    edb, idb = ["e", "f"], ["P", "Q", "R"]
    pool = [X, Y, Z]
    rules = [Rule((Atom("e", (X, Y)),),
                  Atom(str(rng.choice(idb)), (X, Y)), "seed")]
    for i in range(int(rng.integers(2, 6))):
        body = []
        for _ in range(int(rng.integers(1, 3))):
            p = str(rng.choice(edb + idb))
            body.append(Atom(p, (pool[rng.integers(0, 3)],
                                 pool[rng.integers(0, 3)])))
        head_vars = [v for a in body for v in a.args]
        h1 = head_vars[rng.integers(0, len(head_vars))]
        h2 = head_vars[rng.integers(0, len(head_vars))]
        rules.append(Rule(tuple(body),
                          Atom(str(rng.choice(idb)), (h1, h2)), f"g{i}"))
    return Program(rules)


def random_linear(rng) -> Program:
    idb = ["P", "Q", "R"]
    arg_pool = [(X, Y), (Y, X), (X, X)]
    head_pool = arg_pool + [(Y, Y)]
    rules = [Rule((Atom("e", (X, Y)),),
                  Atom(str(rng.choice(idb)), arg_pool[rng.integers(0, 3)]),
                  "seed")]
    for i in range(int(rng.integers(1, 5))):
        b_args = arg_pool[rng.integers(0, 3)]
        h_args = head_pool[rng.integers(0, 4)]
        if not {t for t in h_args} <= {t for t in b_args}:
            continue
        rules.append(Rule((Atom(str(rng.choice(idb)), b_args),),
                          Atom(str(rng.choice(idb)), h_args), f"g{i}"))
    return Program(rules)


def random_base(rng, preds=("e", "f")):
    consts = [f"c{i}" for i in range(int(rng.integers(2, 5)))]
    facts = set()
    for _ in range(int(rng.integers(1, 9))):
        facts.add(Atom(str(rng.choice(list(preds))),
                       (str(rng.choice(consts)), str(rng.choice(consts)))))
    return sorted(facts, key=repr)


# ---------------------------------------------------------------------------
# the oracle
# ---------------------------------------------------------------------------
def assert_all_engines_agree(P, B, monkeypatch, linear: bool = False):
    """Every engine tier × flag combination must reproduce the chase."""
    ch = chase(P, B, max_rounds=MAX_ROUNDS)
    if not ch.terminated:
        return
    expected = set(ch.facts) | set(B)
    eg = min_linear(tglinear(P)) if linear else None
    for pallas in ("0", "1"):
        monkeypatch.setenv("REPRO_USE_PALLAS", pallas)
        for fused in ("0", "1"):
            monkeypatch.setenv("REPRO_FUSED", fused)
            for mode in ("seminaive", "tg", "tg_noopt"):
                kb = EngineKB(P, B)
                materialize(kb, mode=mode, max_rounds=MAX_ROUNDS)
                assert kb.decode_facts() == expected, (
                    f"mode={mode} pallas={pallas} fused={fused}\n{P}")
        if eg is not None:       # tg_linear has no fused variant
            for cleaning in (True, False):
                kb = EngineKB(P, B)
                materialize(kb, mode="tg_linear", tg_eg=eg,
                            cleaning=cleaning)
                assert kb.decode_facts() == expected, (
                    f"tg_linear cleaning={cleaning} pallas={pallas}\n{P}")


@pytest.mark.parametrize("seed", range(6))
def test_differential_datalog(seed, monkeypatch):
    rng = np.random.default_rng(1000 + seed)
    P = random_datalog(rng)
    B = random_base(rng)
    assert_all_engines_agree(P, B, monkeypatch)


@pytest.mark.parametrize("seed", range(4))
def test_differential_linear(seed, monkeypatch):
    rng = np.random.default_rng(2000 + seed)
    P = random_linear(rng)
    B = [f for f in random_base(rng, preds=("e",))]
    if not B:
        return
    assert_all_engines_agree(P, B, monkeypatch, linear=True)


def test_differential_transitive_closure(monkeypatch):
    """Deep fixpoint (the fused while_loop path) on both TC orientations."""
    from repro.core.terms import parse_atom, parse_program
    rng = np.random.default_rng(7)
    edges = ([(i, i + 1) for i in range(20)]
             + [tuple(e) for e in rng.integers(0, 20, (10, 2))])
    B = [parse_atom(f"e(v{a}, v{b})") for a, b in edges]
    for text in ("e(X, Y) -> T(X, Y)\nT(X, Y) & e(Y, Z) -> T(X, Z)",
                 "e(X, Y) -> T(Y, X)\nT(Y, X) & e(Y, Z) -> T(Z, X)"):
        assert_all_engines_agree(parse_program(text), B, monkeypatch)


# ---------------------------------------------------------------------------
# hypothesis-driven cases (runs when the CI dev extra is installed)
# ---------------------------------------------------------------------------
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised in slim containers
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    SETTINGS = dict(deadline=None, max_examples=10,
                    suppress_health_check=[HealthCheck.too_slow])

    @st.composite
    def seeded_case(draw):
        seed = draw(st.integers(0, 2 ** 16))
        return np.random.default_rng(seed)

    @given(seeded_case())
    @settings(**SETTINGS)
    def test_differential_datalog_hypothesis(rng):
        P = random_datalog(rng)
        B = random_base(rng)
        with pytest.MonkeyPatch.context() as mp:
            assert_all_engines_agree(P, B, mp)

    @given(seeded_case())
    @settings(**SETTINGS)
    def test_differential_linear_hypothesis(rng):
        P = random_linear(rng)
        B = random_base(rng, preds=("e",))
        if not B:
            return
        with pytest.MonkeyPatch.context() as mp:
            assert_all_engines_agree(P, B, mp, linear=True)
