"""Optimizer: AdamW convergence, schedule, ZeRO-1 specs, int8 compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.train import optimizer as OPT


def test_adamw_converges_quadratic():
    oc = OPT.OptConfig(lr=0.05, warmup_steps=5, total_steps=300,
                       weight_decay=0.0)
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = OPT.init_opt_state(params, oc)

    @jax.jit
    def step(params, state, i):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return OPT.apply_updates(g, state, params, i, oc)

    for i in range(300):
        params, state, stats = step(params, state, jnp.asarray(i))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_schedule_shape():
    oc = OPT.OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    s0 = float(OPT.schedule(oc, jnp.asarray(0)))
    s10 = float(OPT.schedule(oc, jnp.asarray(10)))
    s100 = float(OPT.schedule(oc, jnp.asarray(100)))
    assert s0 < s10
    assert s100 < s10
    assert s100 >= 0.09 * 1e-3   # cosine floor at 10%


def test_zero1_spec():
    spec = OPT.zero1_spec(P(None, "model"), (128, 64), ("data",), 16)
    assert spec == P(("data",), "model")
    # indivisible: unchanged
    spec2 = OPT.zero1_spec(P(None,), (13,), ("data",), 16)
    assert spec2 == P(None)
    # already DP-sharded (FSDP): unchanged
    spec3 = OPT.zero1_spec(P(("data",), "model"), (128, 64), ("data",), 16)
    assert spec3 == P(("data",), "model")


def test_grad_compress_error_feedback():
    """int8 update compression converges thanks to error feedback."""
    oc = OPT.OptConfig(lr=0.05, warmup_steps=1, total_steps=400,
                       weight_decay=0.0, grad_compress=True)
    target = jnp.asarray([0.3, -0.7, 1.1, 0.0])
    params = {"w": jnp.zeros(4)}
    state = OPT.init_opt_state(params, oc)
    assert "err" in state

    @jax.jit
    def step(params, state, i):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return OPT.apply_updates(g, state, params, i, oc)

    for i in range(400):
        params, state, _ = step(params, state, jnp.asarray(i))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.1)


def test_quantize_int8_roundtrip():
    x = jnp.asarray([0.0, 1.0, -2.0, 0.5])
    q, s = OPT._quantize_int8(x)
    err = np.abs(np.asarray(q, np.float32) * float(s) - np.asarray(x)).max()
    assert err <= float(s)   # quantization error bounded by one step
