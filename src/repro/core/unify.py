"""Homomorphisms, MGUs, CQ containment, instance equivalence (paper §3).

* ``homomorphisms(atoms, instance)`` — all homomorphisms from a conjunction of
  atoms into an instance (backtracking with first-argument indexing).
  Constants map to themselves; *frozen nulls* in the query side (treated as
  constants) map to themselves; variables map to ground terms.
* ``hom_instances(I1, I2)`` — a homomorphism between instances (nulls in I1
  may map to any ground term; constants fixed), i.e. I2 |= I1.
* ``cq_contained(q1, q2)`` — CQ containment via the canonical-database
  (freeze) test [Chandra–Merlin].
* ``mgu(atoms)`` — most general unifier of a set of atoms.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from typing import Iterable, Optional

from repro.core.terms import (Atom, Null, Var, is_const, is_ground, is_null,
                              is_var)


# ---------------------------------------------------------------------------
# instance indexing
# ---------------------------------------------------------------------------
class Index:
    """Per-predicate fact index for join/backtracking."""

    def __init__(self, facts: Iterable[Atom] = ()):
        self.by_pred = defaultdict(list)
        self.facts = set()
        for f in facts:
            self.add(f)

    def add(self, f: Atom) -> bool:
        if f in self.facts:
            return False
        self.facts.add(f)
        self.by_pred[f.pred].append(f)
        return True

    def __contains__(self, f: Atom):
        return f in self.facts

    def __len__(self):
        return len(self.facts)

    def __iter__(self):
        return iter(self.facts)


def _match_atom(pattern: Atom, fact: Atom, sigma: dict) -> Optional[dict]:
    """Extend sigma to map pattern onto fact (pattern may contain vars/nulls;
    nulls on the pattern side are *rigid* unless flex_nulls)."""
    if pattern.pred != fact.pred or pattern.arity != fact.arity:
        return None
    out = dict(sigma)
    for p, f in zip(pattern.args, fact.args):
        if is_var(p):
            if p in out:
                if out[p] != f:
                    return None
            else:
                out[p] = f
        else:
            if p != f:
                return None
    return out


def homomorphisms(atoms, instance, sigma0: Optional[dict] = None,
                  limit: Optional[int] = None):
    """All homomorphisms from ``atoms`` (conjunction, vars flexible) into
    ``instance`` (an Index or iterable of facts)."""
    if not isinstance(instance, Index):
        instance = Index(instance)
    atoms = sorted(atoms, key=lambda a: -sum(1 for t in a.args
                                             if not is_var(t)))
    out = []

    def bt(i, sigma):
        if limit is not None and len(out) >= limit:
            return
        if i == len(atoms):
            out.append(sigma)
            return
        a = atoms[i]
        for f in instance.by_pred.get(a.pred, ()):
            s2 = _match_atom(a, f, sigma)
            if s2 is not None:
                bt(i + 1, s2)

    bt(0, dict(sigma0 or {}))
    return out


def exists_hom(atoms, instance, sigma0=None) -> bool:
    return bool(homomorphisms(atoms, instance, sigma0, limit=1))


# ---------------------------------------------------------------------------
# instance-level homomorphism (nulls flexible)
# ---------------------------------------------------------------------------
def _freeze_nulls_to_vars(atoms):
    """Replace nulls with variables (for instance-hom search)."""
    out = []
    for a in atoms:
        out.append(Atom(a.pred, tuple(
            Var(f"__n{t.nid}") if is_null(t) else t for t in a.args)))
    return out


def instance_hom(I1, I2) -> Optional[dict]:
    """A homomorphism from instance I1 into I2 (maps nulls of I1 to ground
    terms of I2, constants to themselves).  Returns the null mapping or None."""
    q = _freeze_nulls_to_vars(I1)
    homs = homomorphisms(q, I2, limit=1)
    return homs[0] if homs else None


def entails(I2, I1) -> bool:
    """I2 |= I1 (there is a homomorphism I1 -> I2)."""
    return instance_hom(I1, I2) is not None


def equivalent(I1, I2) -> bool:
    return entails(I1, I2) and entails(I2, I1)


# ---------------------------------------------------------------------------
# CQ containment (freeze test)
# ---------------------------------------------------------------------------
def cq_contained(head_vars1, body1, head_vars2, body2) -> bool:
    """Q1 ⊆ Q2 iff the frozen head tuple of Q1 is an answer of Q2 on the
    canonical database of Q1."""
    freeze = {}
    for a in body1:
        for t in a.args:
            if is_var(t) and t not in freeze:
                freeze[t] = f"~f{len(freeze)}_{t.name}"
    canon = [a.subst(freeze) for a in body1]
    target = [freeze.get(v, v) for v in head_vars1]
    sigma0 = {}
    if len(head_vars1) != len(head_vars2):
        return False
    for v2, t in zip(head_vars2, target):
        if is_var(v2):
            if v2 in sigma0 and sigma0[v2] != t:
                return False
            sigma0[v2] = t
        elif v2 != t:
            return False
    return exists_hom(body2, canon, sigma0)


# ---------------------------------------------------------------------------
# MGU
# ---------------------------------------------------------------------------
def mgu(atoms) -> Optional[dict]:
    """Most general unifier of a set of atoms (vars over terms)."""
    atoms = list(atoms)
    if not atoms:
        return {}
    eqs = []
    first = atoms[0]
    for other in atoms[1:]:
        if other.pred != first.pred or other.arity != first.arity:
            return None
        eqs.extend(zip(first.args, other.args))
    sigma = {}

    def walk(t):
        while is_var(t) and t in sigma:
            t = sigma[t]
        return t

    while eqs:
        a, b = eqs.pop()
        a, b = walk(a), walk(b)
        if a == b:
            continue
        if is_var(a):
            sigma[a] = b
        elif is_var(b):
            sigma[b] = a
        else:
            return None
    # path-compress
    def resolve(t):
        seen = set()
        while is_var(t) and t in sigma and t not in seen:
            seen.add(t)
            t = sigma[t]
        return t
    return {v: resolve(v) for v in sigma}
