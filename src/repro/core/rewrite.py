"""EG-rewritings (paper Def. 17) and characteristic queries.

The EG-rewriting of a node v unfolds rule(v)'s body backwards through v's
*specific* ancestors (one parent per body position — the guided variant of
XRewrite) until only extensional atoms remain.  Lemma 18: answers of rew(v)
on B = facts of v(B).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.core.terms import Atom, Program, Rule, Var, is_var
from repro.core.unify import cq_contained, mgu


@dataclass
class CQ:
    head_args: tuple          # terms (vars/consts)
    body: tuple               # tuple[Atom], extensional only when complete

    def __repr__(self):
        b = " & ".join(map(str, self.body))
        return f"Q({', '.join(map(str, self.head_args))}) <- {b}"


def eg_rewriting(eg, v: int, max_atoms: int = 256) -> Optional[CQ]:
    """Def. 17.  Returns None if the rewriting exceeds ``max_atoms`` (guard
    for deep graphs; callers must treat None as 'unknown')."""
    program = eg.program
    counter = itertools.count()

    def fresh_rule(rule: Rule) -> Rule:
        return rule.rename_apart(f"_{next(counter)}")

    rv = fresh_rule(eg.rule_of[v])
    head_args = rv.head.args
    # worklist of (atom, node) — node provides the unfolding rule
    pending: List[Tuple[Atom, Optional[int]]] = []
    done: List[Atom] = []
    sigma_total: Dict = {}

    def push_body(rule: Rule, node: int):
        for j, a in enumerate(rule.body):
            if a.pred in program.edb:
                done.append(a)
            else:
                parent = eg.parents(node).get(j)
                pending.append((a, parent))

    push_body(rv, v)
    while pending:
        if len(done) + len(pending) > max_atoms:
            return None
        alpha, u = pending.pop()
        alpha = alpha.subst(sigma_total)
        if u is None:
            # dangling intensional atom (shouldn't happen in well-formed EGs)
            done.append(alpha)
            continue
        ru = fresh_rule(eg.rule_of[u])
        theta = mgu([ru.head, alpha])
        if theta is None:
            # unsatisfiable unfolding: rewriting denotes the empty query
            return CQ(head_args=tuple(), body=(Atom("__false", ()),))
        sigma_total = {**{k: _apply(theta, t) for k, t in sigma_total.items()},
                       **theta}
        done[:] = [a.subst(theta) for a in done]
        pending[:] = [(a.subst(theta), n) for a, n in pending]
        head_args = tuple(_apply(theta, t) for t in head_args)
        for j, a in enumerate(ru.body):
            a = a.subst(theta)
            if a.pred in program.edb:
                done.append(a)
            else:
                parent = eg.parents(u).get(j)
                pending.append((a, parent))
    return CQ(head_args=head_args, body=tuple(done))


def _apply(theta, t):
    return theta.get(t, t) if is_var(t) else t


def rewriting_contained(q1: CQ, q2: CQ) -> bool:
    """q1 ⊆ q2 via the freeze test."""
    if q1 is None or q2 is None:
        return False
    if any(a.pred == "__false" for a in q1.body):
        return True           # empty query contained in everything
    if any(a.pred == "__false" for a in q2.body):
        return False
    return cq_contained(q1.head_args, q1.body, q2.head_args, q2.body)
