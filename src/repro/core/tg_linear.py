"""Instance-independent TGs for linear programs (paper §5).

* ``canonical_facts(P)`` — H(P): one representative base fact per
  pattern-isomorphism class (set partitions of argument positions) per EDB
  predicate.
* ``tglinear(P)`` — Algorithm 1: chase each canonical fact (equivalent-chase
  variant, Thm. 10), track the chase graph, emit one node per rule execution
  chained along derivations; union across canonical facts with rule-path
  sharing (a trie), which preserves Def. 4's one-parent-per-position shape.
* ``min_linear(G)`` — Defs. 12–14: exhaustively remove nodes dominated via
  *preserving homomorphisms* (nulls shared with ancestor instances are rigid).
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from typing import Dict, List

from repro.core.chase import _NullFactory, chase
from repro.core.eg import EG, evaluate
from repro.core.terms import Atom, Program, Rule, Var, is_null
from repro.core.unify import Index, homomorphisms


# ---------------------------------------------------------------------------
# H(P): canonical facts modulo pattern isomorphism
# ---------------------------------------------------------------------------
def _set_partitions(n: int):
    """All partitions of range(n) (Bell(n) of them) as tuples of block ids."""
    if n == 0:
        yield ()
        return

    def rec(i, assignment, nblocks):
        if i == n:
            yield tuple(assignment)
            return
        for b in range(nblocks + 1):
            assignment.append(b)
            yield from rec(i + 1, assignment, max(nblocks, b + 1))
            assignment.pop()

    yield from rec(0, [], 0)


def canonical_facts(program: Program) -> List[Atom]:
    out = []
    fresh = 0
    for p in sorted(program.edb):
        ar = program.arities[p]
        for part in _set_partitions(ar):
            consts = {}
            args = []
            for b in part:
                if b not in consts:
                    consts[b] = f"c{fresh}"
                    fresh += 1
                args.append(consts[b])
            out.append(Atom(p, tuple(args)))
    return out


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------
def tglinear(program: Program, max_rounds: int = 64) -> EG:
    program = program.normalize()
    assert program.is_linear, "tglinear requires a linear program"
    eg = EG(program)
    trie: Dict[tuple, int] = {}          # rule-path -> node id

    def node_for(path: tuple, rule: Rule, parent_key):
        if path in trie:
            return trie[path]
        nid = eg.add_node(rule)
        trie[path] = nid
        if parent_key is not None:
            eg.add_edge(trie[parent_key], 1 - 1, nid)  # single body atom: j=0
        return nid

    for f in canonical_facts(program):
        res = chase(program, [f], variant="equivalent", track_graph=True,
                    max_rounds=max_rounds)
        # fact -> list of rule-paths of nodes that derived it
        paths_of: Dict[Atom, List[tuple]] = defaultdict(list)
        paths_of[f] = [()]
        # graph edges are recorded in derivation (round) order
        for body_facts, rule, fact in res.graph:
            src = body_facts[0]
            for ppath in paths_of.get(src, []):
                path = ppath + (rule.name,)
                node_for(path, rule, ppath if ppath else None)
                if path not in paths_of[fact]:
                    paths_of[fact].append(path)
        # root nodes: extensional rule executions start chains from f itself
        # (handled above since paths_of[f] = [()], parent_key None)
    return eg


# ---------------------------------------------------------------------------
# minLinear (Defs. 12-14)
# ---------------------------------------------------------------------------
def _preserving_hom_exists(u_facts, v_facts, rigid_nulls) -> bool:
    """Hom from u_facts into v_facts mapping rigid nulls to themselves and
    other nulls anywhere (constants fixed)."""
    qvars = {}
    query = []
    for a in u_facts:
        args = []
        for t in a.args:
            if is_null(t) and t not in rigid_nulls:
                args.append(qvars.setdefault(t, Var(f"__h{t.nid}")))
            else:
                args.append(t)
        query.append(Atom(a.pred, tuple(args)))
    return bool(homomorphisms(query, v_facts, limit=1))


def _dominates(eg: EG, evals, v: int, u: int) -> bool:
    """True if u is dominated by v: preserving hom u({f}) -> v({f}) ∀f."""
    for f, ev in evals.items():
        uf = ev.node_facts.get(u, set())
        vf = ev.node_facts.get(v, set())
        anc = eg.ancestors(u)
        anc_nulls = set()
        for w in anc:
            for a in ev.node_facts.get(w, set()):
                anc_nulls.update(t for t in a.args if is_null(t))
        if not _preserving_hom_exists(uf, vf, anc_nulls):
            return False
    return True


def min_linear(eg: EG) -> EG:
    eg = eg.copy()        # never mutate the caller's TG
    program = eg.program
    H = canonical_facts(program)

    def all_evals():
        return {f: evaluate(eg, [f]) for f in H}

    changed = True
    while changed:
        changed = False
        evals = all_evals()
        nodes = eg.topo_order()
        for u in nodes:
            if u not in eg.rule_of:
                continue
            for v in nodes:
                if v == u or v not in eg.rule_of or u not in eg.rule_of:
                    continue
                if eg.rule_of[v].head.pred != eg.rule_of[u].head.pred:
                    continue
                if u in eg.ancestors(v):
                    continue   # dominator must survive u's removal
                if _dominates(eg, evals, v, u):
                    # redirect u's children to v, then drop u
                    for w in eg.children(u):
                        for j, pu in list(eg.parent[w].items()):
                            if pu == u:
                                del eg.parent[w][j]
                                eg.add_edge(v, j, w)
                    eg.remove_node(u)
                    changed = True
                    break
            if changed:
                break
    return eg
