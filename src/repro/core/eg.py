"""Execution Graphs / Trigger Graphs (paper §4, Defs. 4–6).

An EG is an acyclic digraph: nodes are labelled with rules; an intensional
node ``v`` has at most one incoming edge per body position ``j`` (``u ->_j
v``), so different parent combinations yield different nodes (Def. 9).

``evaluate(eg, base)`` implements Def. 5: reasoning guided by the graph —
extensional nodes evaluate their rule over B; intensional nodes evaluate over
the union of their parents' instances, with body atom j restricted to the
j-th parent's facts.  ``G(B) = B ∪ ⋃_v v(B)``.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.chase import _NullFactory, chase
from repro.core.terms import Atom, Null, Program, Rule, Var, is_var
from repro.core.unify import Index, entails, equivalent, homomorphisms


class EG:
    def __init__(self, program: Program):
        self.program = program
        self.rule_of: Dict[int, Rule] = {}
        self.parent: Dict[int, Dict[int, int]] = defaultdict(dict)  # v -> {j: u}
        self._next = 0

    # ---------------- construction ----------------
    def add_node(self, rule: Rule) -> int:
        nid = self._next
        self._next += 1
        self.rule_of[nid] = rule
        return nid

    def add_edge(self, u: int, j: int, v: int):
        assert j not in self.parent[v], "one incoming edge per body position"
        self.parent[v][j] = u

    def remove_node(self, v: int):
        del self.rule_of[v]
        self.parent.pop(v, None)
        for w, ps in self.parent.items():
            for j, u in list(ps.items()):
                if u == v:
                    del ps[j]

    def copy(self) -> "EG":
        out = EG(self.program)
        out.rule_of = dict(self.rule_of)
        out.parent = defaultdict(dict, {v: dict(ps)
                                        for v, ps in self.parent.items()})
        out._next = self._next
        return out

    # ---------------- structure ----------------
    @property
    def nodes(self):
        return list(self.rule_of)

    @property
    def num_edges(self):
        return sum(len(ps) for v, ps in self.parent.items()
                   if v in self.rule_of)

    def parents(self, v: int):
        return self.parent.get(v, {})

    def children(self, v: int):
        out = []
        for w, ps in self.parent.items():
            if w in self.rule_of and v in ps.values():
                out.append(w)
        return out

    def depth(self, v: int, memo=None) -> int:
        memo = memo if memo is not None else {}
        if v in memo:
            return memo[v]
        ps = self.parents(v)
        d = 0 if not ps else 1 + max(self.depth(u, memo) for u in ps.values())
        memo[v] = d
        return d

    def graph_depth(self) -> int:
        memo = {}
        return max((self.depth(v, memo) for v in self.rule_of), default=0)

    def ancestors(self, v: int):
        out = set()
        stack = [v]
        while stack:
            x = stack.pop()
            for u in self.parents(x).values():
                if u not in out:
                    out.add(u)
                    stack.append(u)
        return out

    def topo_order(self):
        memo = {}
        return sorted(self.rule_of, key=lambda v: (self.depth(v, memo), v))

    def stats(self):
        return {"nodes": len(self.rule_of), "edges": self.num_edges,
                "depth": self.graph_depth()}


# ---------------------------------------------------------------------------
# Def. 5 evaluation
# ---------------------------------------------------------------------------
def _positional_homs(body, per_atom_indices):
    """Homomorphisms h from the body s.t. h(body[j]) ∈ per_atom_indices[j]."""
    order = sorted(range(len(body)), key=lambda j: 0)  # keep given order
    out = []

    def bt(i, sigma):
        if i == len(order):
            out.append(sigma)
            return
        j = order[i]
        a = body[j]
        for f in per_atom_indices[j].by_pred.get(a.pred, ()):
            s2 = _match(a, f, sigma)
            if s2 is not None:
                bt(i + 1, s2)

    def _match(pattern, fact, sigma):
        if pattern.arity != fact.arity:
            return None
        o = dict(sigma)
        for p, fv in zip(pattern.args, fact.args):
            if is_var(p):
                if p in o:
                    if o[p] != fv:
                        return None
                else:
                    o[p] = fv
            elif p != fv:
                return None
        return o

    bt(0, {})
    return out


@dataclass
class EvalResult:
    node_facts: Dict[int, set]
    instance: Index
    triggers: int

    @property
    def facts(self):
        return set(self.instance.facts)


def evaluate(eg: EG, base, nulls: Optional[_NullFactory] = None,
             count_triggers: bool = True) -> EvalResult:
    """Reason over base via the EG (Def. 5)."""
    program = eg.program
    nf = nulls or _NullFactory()
    base_idx = Index(base)
    node_facts: Dict[int, set] = {}
    triggers = 0
    for v in eg.topo_order():
        rule = eg.rule_of[v]
        n = len(rule.body)
        ps = eg.parents(v)
        if not ps:
            homs = homomorphisms(rule.body, base_idx)
        else:
            per_atom = []
            for j in range(n):
                u = ps.get(j)
                per_atom.append(Index(node_facts.get(u, set())) if u is not None
                                else base_idx)
            homs = _positional_homs(rule.body, per_atom)
        facts = set()
        for h in homs:
            triggers += 1
            hs = dict(h)
            for z in rule.existentials:
                key = tuple(h.get(x) for x in rule.frontier)
                hs[z] = nf.skolem(rule, Var(f"{z.name}@{v}"), key)
            facts.add(rule.head.subst(hs))
        node_facts[v] = facts
    inst = Index(base_idx.facts)
    for fs in node_facts.values():
        for f in fs:
            inst.add(f)
    return EvalResult(node_facts=node_facts, instance=inst, triggers=triggers)


# ---------------------------------------------------------------------------
# Def. 6 check (test utility): G is a TG for (P,B) iff G(B) answers every BCQ
# like (P,B) — instance hom-equivalence is a sufficient certificate.
# ---------------------------------------------------------------------------
def is_tg_for(eg: EG, program: Program, base, chase_variant="restricted") \
        -> bool:
    g_res = evaluate(eg, base)
    ch = chase(program, base, variant=chase_variant)
    assert ch.terminated
    # soundness: G(B) entailed by chase; completeness: chase entailed by G(B)
    return (entails(ch.facts, g_res.facts)
            and entails(g_res.facts, ch.facts))
