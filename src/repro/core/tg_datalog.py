"""Instance-dependent TGs for Datalog (paper §4 construction + §6
optimizations): level-k full EG (Φ^k), minDatalog (Def. 19), the Def. 23
rule-execution strategy, and TGmat (Algorithm 2, Thm. 24).

Scalability notes (symbolic layer): Def. 9 generates every k-compatible
parent combination; we additionally prune nodes whose instance is empty on
the given base (instance-dependent TGs may do this without losing
completeness — an empty node contributes no facts and its descendants are
empty) and apply minDatalog each level, per Algorithm 2 line 5.  The
vectorized engine (repro.engine) coalesces combination nodes per
(rule, delta-position); the semantics is the same union of rule executions.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

from repro.core.chase import _NullFactory
from repro.core.eg import EG, _positional_homs
from repro.core.rewrite import eg_rewriting, rewriting_contained
from repro.core.terms import Atom, Program, Rule
from repro.core.unify import Index


class TGmatState:
    def __init__(self, program: Program, base):
        self.program = program.normalize()
        self.eg = EG(self.program)
        self.base_idx = Index(base)
        self.node_facts: Dict[int, Set[Atom]] = {}
        self.node_depth: Dict[int, int] = {}
        self.instance = Index(base)
        self.triggers = 0
        self.rewritings = {}

    def rew(self, v):
        if v not in self.rewritings:
            self.rewritings[v] = eg_rewriting(self.eg, v)
        return self.rewritings[v]


def _eval_node(st: TGmatState, v: int, restrict_to_new: bool = True):
    """Def. 5 evaluation of one node with the Def. 23 execution strategy.

    With ``restrict_to_new`` we (a) pick a body atom whose variables cover
    the head variables and antijoin its facts against the already-derived
    head relation *before* enumerating homomorphisms (step (v)/(vi) of
    Example 22 — this is what reduces the trigger count), and (b) drop
    derived facts already in the global instance (v(B,I) = v(B) \\ I,
    Claim 40)."""
    rule = st.eg.rule_of[v]
    ps = st.eg.parents(v)
    n = len(rule.body)
    if not ps:
        per_atom = [st.base_idx] * n
    else:
        per_atom = []
        for j in range(n):
            u = ps.get(j)
            per_atom.append(Index(st.node_facts.get(u, set()))
                            if u is not None else st.base_idx)

    if restrict_to_new:
        head_vars = [t for t in rule.head.args]
        hv_set = {t for t in head_vars}
        derived = st.instance.by_pred.get(rule.head.pred, ())
        if derived:
            derived_set = set(derived)
            for j in range(n):
                aj = rule.body[j]
                pos_of = {}
                for i, t in enumerate(aj.args):
                    pos_of.setdefault(t, i)
                if all((not hasattr(tv, "name")) or tv in pos_of
                       for tv in hv_set):
                    # antijoin: keep only facts whose induced head tuple is new
                    kept = Index()
                    for f in per_atom[j].by_pred.get(aj.pred, ()):
                        ht = tuple(
                            f.args[pos_of[t]] if t in pos_of else t
                            for t in rule.head.args)
                        if Atom(rule.head.pred, ht) not in derived_set:
                            kept.add(f)
                    per_atom = list(per_atom)
                    per_atom[j] = kept
                    break

    homs = _positional_homs(rule.body, per_atom)
    st.triggers += len(homs)
    facts = set()
    for h in homs:
        f = rule.head.subst(h)
        if restrict_to_new and f in st.instance:
            continue
        facts.add(f)
    return facts


def _expand_level(st: TGmatState, k: int) -> List[int]:
    """Add level-k nodes (paper-depth k): k=1 extensional rules; k>=2 every
    k-compatible combination (Def. 9), deduped by (rule, parent-tuple)."""
    eg = st.eg
    new_nodes = []
    if k == 1:
        for r in st.program.extensional_rules():
            v = eg.add_node(r)
            st.node_depth[v] = 1
            new_nodes.append(v)
        return new_nodes

    # candidate providers per predicate, by depth
    by_pred = defaultdict(list)
    for v in eg.rule_of:
        by_pred[eg.rule_of[v].head.pred].append(v)
    seen_combos = set()
    for r in st.program.intensional_rules():
        options = []
        feasible = True
        for a in r.body:
            if a.pred in st.program.edb:
                options.append([None])          # base-instance position
                continue
            cands = [u for u in by_pred.get(a.pred, [])
                     if st.node_depth[u] < k]
            if not cands:
                feasible = False
                break
            options.append(cands)
        if not feasible:
            continue
        for combo in itertools.product(*options):
            if not any(u is not None and st.node_depth[u] == k - 1
                       for u in combo):
                continue
            key = (r.name, combo)
            if key in seen_combos:
                continue
            seen_combos.add(key)
            v = eg.add_node(r)
            st.node_depth[v] = k
            for j, u in enumerate(combo):
                if u is not None:
                    eg.add_edge(u, j, v)
            new_nodes.append(v)
    return new_nodes


def min_datalog_level(st: TGmatState, new_nodes: List[int]) -> List[int]:
    """Def. 19 applied to the fresh level: remove v if some surviving u with
    depth(u) <= depth(v), same head predicate, and rew(v) ⊆ rew(u)."""
    eg = st.eg
    survivors = []
    old_nodes = [u for u in eg.rule_of if u not in new_nodes]
    for v in new_nodes:
        dominated_by = None
        rv = st.rew(v)
        for u in old_nodes + survivors:
            if u == v or eg.rule_of[u].head.pred != eg.rule_of[v].head.pred:
                continue
            if st.node_depth[u] > st.node_depth[v]:
                continue
            if rewriting_contained(rv, st.rew(u)):
                dominated_by = u
                break
        if dominated_by is None:
            survivors.append(v)
        else:
            eg.remove_node(v)
            st.rewritings.pop(v, None)
            st.node_depth.pop(v, None)
    return survivors


def tgmat(program: Program, base, *, use_min: bool = True,
          use_ruleexec: bool = True, max_rounds: int = 10_000):
    """Algorithm 2.  Returns (instance, eg, stats).

    ``use_min``      — apply minDatalog per level (column 'm')
    ``use_ruleexec`` — Def. 23 new-facts-only restriction (column 'm+r');
                       disabling it still dedupes facts globally at the end of
                       each round (the chase-equivalent 'No opt' baseline
                       keeps per-node instances unfiltered).
    """
    assert program.is_datalog, "TGmat targets Datalog programs"
    st = TGmatState(program, base)
    k = 0
    while k < max_rounds:
        k += 1
        new_nodes = _expand_level(st, k)
        if use_min and k > 1:
            new_nodes = min_datalog_level(st, new_nodes)
        any_new_fact = False
        for v in new_nodes:
            facts = _eval_node(st, v, restrict_to_new=use_ruleexec)
            if not use_ruleexec:
                facts = {f for f in facts if f not in st.instance}
            if facts:
                st.node_facts[v] = facts
                any_new_fact = True
                # running instance (I grows within the round: Def. 23 allows
                # any I ⊆ G(B); GLog executes nodes sequentially, Example 22)
                for f in facts:
                    st.instance.add(f)
            else:
                # instance-dependent pruning: empty nodes are dropped
                st.eg.remove_node(v)
                st.node_depth.pop(v, None)
                st.rewritings.pop(v, None)
        if not any_new_fact:
            break
    stats = {"rounds": k, "triggers": st.triggers,
             **st.eg.stats(),
             "derived": len(st.instance) - len(st.base_idx)}
    return st.instance, st.eg, stats
