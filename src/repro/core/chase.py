"""Breadth-first chase variants (paper §3) with semi-naive evaluation (SNE),
trigger counting and chase-graph tracking.

Variants
--------
* ``restricted`` — a trigger is *active* if its head instantiation has no
  extension-homomorphism into the current instance (VLog's variant; for
  Datalog this degenerates to fact membership).
* ``skolem``     — existentials become deterministic skolem nulls keyed by
  (rule, frontier binding); add-if-absent (RDFox/COM variant).
* ``equivalent`` — no applicability checks; fresh nulls per trigger; stops
  when the round output is logically entailed by the previous instance
  (guarantees termination for FES programs; used by tglinear/Thm. 10).
* ``oblivious``  — fresh nulls, no checks, no entailment test (bounded by
  ``max_rounds``; analysis tool only).

The chase is the paper's *baseline* against which TGs are measured; the
trigger count is the hardware-independent work metric of Table 5/8a.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Optional

from repro.core.terms import Atom, Null, Program, Rule, Var, is_var
from repro.core.unify import Index, entails, exists_hom, homomorphisms


@dataclass
class ChaseResult:
    instance: Index
    rounds: int
    triggers: int
    derived: int
    graph: list = field(default_factory=list)   # (body_facts, rule, fact)
    per_round: list = field(default_factory=list)
    terminated: bool = True

    @property
    def facts(self):
        return set(self.instance.facts)


class _NullFactory:
    def __init__(self):
        self.count = 0
        self.skolem_memo = {}

    def fresh(self) -> Null:
        self.count += 1
        return Null(self.count)

    def skolem(self, rule: Rule, var: Var, frontier_binding: tuple) -> Null:
        key = (rule.name, var.name, frontier_binding)
        if key not in self.skolem_memo:
            self.skolem_memo[key] = self.fresh()
        return self.skolem_memo[key]


def _round_triggers(program: Program, full: Index, delta: set,
                    first_round: bool):
    """Semi-naive trigger enumeration: each trigger must use >= 1 delta fact
    (round 1: all body positions over the base instance)."""
    seen = set()
    for rule in program:
        n = len(rule.body)
        if first_round:
            for h in homomorphisms(rule.body, full):
                key = (rule.name, tuple(sorted(h.items())))
                if key not in seen:
                    seen.add(key)
                    yield rule, h
            continue
        if not delta:
            continue
        delta_idx = Index(delta)
        for j in range(n):
            a_j = rule.body[j]
            for hj in homomorphisms([a_j], delta_idx):
                rest = [rule.body[i] for i in range(n) if i != j]
                for h in homomorphisms(rest, full, sigma0=hj):
                    key = (rule.name, tuple(sorted(h.items())))
                    if key not in seen:
                        seen.add(key)
                        yield rule, h


def chase(program: Program, base, variant: str = "restricted",
          max_rounds: int = 10_000, track_graph: bool = False,
          nulls: Optional[_NullFactory] = None) -> ChaseResult:
    program = program.normalize()
    nf = nulls or _NullFactory()
    inst = Index(base)
    delta = set(inst.facts)
    total_triggers = 0
    derived = 0
    graph = []
    per_round = []
    rounds = 0
    terminated = False

    for k in range(1, max_rounds + 1):
        new_facts = set()
        round_triggers = 0
        for rule, h in _round_triggers(program, inst, delta, k == 1):
            round_triggers += 1
            frontier_binding = tuple(h[v] for v in rule.frontier)
            if variant == "restricted":
                # active? no extension hom of head into inst
                head_inst = rule.head.subst(h)
                if exists_hom([head_inst], inst):
                    continue
                hs = dict(h)
                for z in rule.existentials:
                    hs[z] = nf.fresh()
            elif variant == "skolem":
                hs = dict(h)
                for z in rule.existentials:
                    hs[z] = nf.skolem(rule, z, frontier_binding)
            else:  # equivalent / oblivious
                hs = dict(h)
                for z in rule.existentials:
                    hs[z] = nf.fresh()
            fact = rule.head.subst(hs)
            if fact in inst or fact in new_facts:
                continue
            new_facts.add(fact)
            if track_graph:
                body_facts = tuple(a.subst(h) for a in rule.body)
                graph.append((body_facts, rule, fact))
        total_triggers += round_triggers
        per_round.append((round_triggers, len(new_facts)))
        if variant == "skolem" or variant == "restricted":
            if not new_facts:
                terminated = True
                rounds = k - 1
                break
        elif variant == "equivalent":
            if not new_facts or entails(inst.facts, new_facts):
                terminated = True
                rounds = k - 1
                break
        else:  # oblivious
            if not new_facts:
                terminated = True
                rounds = k - 1
                break
        for f in new_facts:
            inst.add(f)
        derived += len(new_facts)
        delta = new_facts
        rounds = k
    return ChaseResult(instance=inst, rounds=rounds, triggers=total_triggers,
                       derived=derived, graph=graph, per_round=per_round,
                       terminated=terminated)


def certain_answer_bcq(program: Program, base, query_atoms) -> bool:
    """(P,B) |= Q via a terminating chase (restricted) + hom test."""
    res = chase(program, base, variant="restricted")
    return exists_hom(query_atoms, res.instance)
