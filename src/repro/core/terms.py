"""Terms, atoms, rules, programs (paper §3) + a small rule parser.

Representation
--------------
* constants: plain python strings (or ints once dictionary-encoded)
* variables: ``Var(name)``
* nulls:     ``Null(id)`` — labelled nulls introduced for existentials
* atom:      ``Atom(pred, args)`` (args: tuple of terms)
* rule:      ``Rule(body, head)`` — single-head (form (1) of the paper);
             existential variables = head vars not occurring in the body.

Rule text syntax (parser):  ``p(X,Y) & q(Y,Z) -> r(X,Z)`` with existentials
written as head variables that don't appear in the body.
Capitalised identifiers are variables; everything else is a constant.
"""
from __future__ import annotations

import itertools
import re
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence


@dataclass(frozen=True, order=True)
class Var:
    name: str

    def __repr__(self):
        return f"?{self.name}"


@dataclass(frozen=True, order=True)
class Null:
    nid: int

    def __repr__(self):
        return f"_n{self.nid}"


Term = object   # Var | Null | str/int constant


def is_var(t) -> bool:
    return isinstance(t, Var)


def is_null(t) -> bool:
    return isinstance(t, Null)


def is_const(t) -> bool:
    return not isinstance(t, (Var, Null))


def is_ground(t) -> bool:
    return not isinstance(t, Var)


@dataclass(frozen=True, order=True)
class Atom:
    pred: str
    args: tuple

    def __repr__(self):
        return f"{self.pred}({', '.join(map(str, self.args))})"

    @property
    def arity(self):
        return len(self.args)

    def vars(self):
        return [t for t in self.args if is_var(t)]

    def subst(self, sigma: dict) -> "Atom":
        return Atom(self.pred, tuple(sigma.get(t, t) for t in self.args))


@dataclass(frozen=True)
class Rule:
    body: tuple          # tuple[Atom]
    head: Atom
    name: str = ""

    def __repr__(self):
        b = " & ".join(map(str, self.body))
        return f"[{self.name}] {b} -> {self.head}"

    @property
    def frontier(self):
        """head vars that occur in the body"""
        bv = self.body_vars()
        return [v for v in self.head.vars() if v in bv]

    def body_vars(self):
        out = []
        for a in self.body:
            for v in a.vars():
                if v not in out:
                    out.append(v)
        return out

    @property
    def existentials(self):
        bv = set(self.body_vars())
        out = []
        for v in self.head.vars():
            if v not in bv and v not in out:
                out.append(v)
        return out

    @property
    def is_datalog(self):
        return not self.existentials

    @property
    def is_linear(self):
        return len(self.body) == 1

    def rename_apart(self, suffix: str) -> "Rule":
        sigma = {}
        for v in set(self.body_vars()) | set(self.head.vars()):
            sigma[v] = Var(v.name + suffix)
        return Rule(tuple(a.subst(sigma) for a in self.body),
                    self.head.subst(sigma), self.name)


class Program:
    """A set of rules + EDB/IDB bookkeeping (paper assumes rule bodies are
    homogeneous: all-EDB or all-IDB; ``normalize()`` enforces it)."""

    def __init__(self, rules: Sequence[Rule]):
        self.rules = list(rules)
        for i, r in enumerate(self.rules):
            if not r.name:
                self.rules[i] = Rule(r.body, r.head, f"r{i+1}")
        self.idb = {r.head.pred for r in self.rules}
        self.edb = {a.pred for r in self.rules for a in r.body} - self.idb
        self.arities = {}
        for r in self.rules:
            for a in list(r.body) + [r.head]:
                self.arities.setdefault(a.pred, a.arity)

    def __iter__(self):
        return iter(self.rules)

    def __len__(self):
        return len(self.rules)

    def __repr__(self):
        return "\n".join(map(str, self.rules))

    @property
    def is_datalog(self):
        return all(r.is_datalog for r in self.rules)

    @property
    def is_linear(self):
        return all(r.is_linear for r in self.rules)

    def extensional_rules(self):
        return [r for r in self.rules if all(a.pred in self.edb
                                             for a in r.body)]

    def intensional_rules(self):
        return [r for r in self.rules if any(a.pred in self.idb
                                             for a in r.body)]

    def normalize(self) -> "Program":
        """Ensure every rule body is all-EDB or all-IDB by introducing an IDB
        twin ``P~aux`` for each EDB predicate used in a mixed body."""
        mixed_preds = set()
        for r in self.rules:
            preds = {a.pred for a in r.body}
            if preds & self.edb and preds & self.idb:
                mixed_preds |= (preds & self.edb)
        if not mixed_preds:
            return self
        new_rules = []
        aux = {}
        for p in sorted(mixed_preds):
            ar = self.arities[p]
            vs = tuple(Var(f"U{i}") for i in range(ar))
            aux[p] = f"{p}~aux"
            new_rules.append(Rule((Atom(p, vs),), Atom(aux[p], vs),
                                  f"aux_{p}"))
        for r in self.rules:
            preds = {a.pred for a in r.body}
            if preds & self.edb and preds & self.idb:
                body = tuple(Atom(aux.get(a.pred, a.pred), a.args)
                             if a.pred in aux else a for a in r.body)
                new_rules.append(Rule(body, r.head, r.name))
            else:
                new_rules.append(r)
        return Program(new_rules)


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------
_ATOM_RE = re.compile(r"([\w~]+)\s*\(([^)]*)\)")


def _parse_term(tok: str):
    tok = tok.strip()
    if tok and (tok[0].isupper() or tok[0] == "?"):
        return Var(tok.lstrip("?"))
    return tok


def parse_atom(s: str) -> Atom:
    m = _ATOM_RE.match(s.strip())
    if not m:
        raise ValueError(f"bad atom: {s}")
    pred = m.group(1)
    args = tuple(_parse_term(t) for t in m.group(2).split(",") if t.strip()) \
        if m.group(2).strip() else ()
    return Atom(pred, args)


def parse_rule(s: str, name: str = "") -> Rule:
    lhs, rhs = s.split("->")
    body = tuple(parse_atom(a) for a in re.split(r"[&,](?![^()]*\))", lhs)
                 if a.strip())
    rhs = rhs.replace("exists", "").strip()
    if "." in rhs:
        rhs = rhs.split(".", 1)[1]
    head = parse_atom(rhs)
    return Rule(body, head, name)


def parse_program(text: str) -> Program:
    rules = []
    for i, line in enumerate(l for l in text.strip().splitlines()
                             if l.strip() and not l.strip().startswith("#")):
        rules.append(parse_rule(line, f"r{i+1}"))
    return Program(rules)


def example1_program() -> Program:
    """The paper's Example 1 (P1)."""
    return parse_program("""
        r(X, Y) -> R(X, Y)
        R(X, Y) -> T(Y, X, Y)
        T(Y, X, Y) -> R(X, Y)
        r(X, Y) -> exists Z. T(Y, X, Z)
    """)
