"""Serving launcher: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Prefill + batched greedy decode with jit-cached steps and sequence-sharded
KV caches (see DESIGN.md §5).  On CPU use --smoke (reduced config).
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_smoke_config
from repro.launch.mesh import make_host_mesh, make_mesh_ctx
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    mesh = make_host_mesh(dp=1, tp=jax.device_count())
    mcx = make_mesh_ctx(mesh)
    mdl = M.build(cfg, mcx)
    params = mdl.init_params(jax.random.PRNGKey(0))

    B, S = args.batch, args.prompt_len
    if cfg.input_mode == "embeddings":
        batch = {"embeddings": jax.random.normal(
            jax.random.PRNGKey(1), (B, S, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)}
    prefill = jax.jit(mdl.prefill_step)
    decode = jax.jit(mdl.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    tok, caches = prefill(params, batch)
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0
    out = [np.asarray(tok)]
    t0 = time.perf_counter()
    for t in range(args.gen - 1):
        if cfg.input_mode == "embeddings":
            step_in = jax.random.normal(jax.random.PRNGKey(2 + t),
                                        (B, 1, cfg.d_model), jnp.float32)
        else:
            step_in = tok
        tok, caches = decode(params, caches, step_in,
                             jnp.asarray(S + t, jnp.int32))
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    gen = np.stack(out, 1)
    print(f"[serve] {cfg.name}: prefill({B}x{S})={t_prefill*1e3:.0f}ms  "
          f"decode {args.gen} toks: {t_decode/max(args.gen-1,1)*1e3:.1f}ms/tok")
    print(f"[serve] sample: {gen[0][:16]}")


if __name__ == "__main__":
    main()
