"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The single-pod mesh is
(16, 16) = 256 chips ("data", "model"); the multi-pod mesh is (2, 16, 16) =
512 chips ("pod", "data", "model") — "pod" is a second data-parallel tier
whose collectives cross the inter-pod links (DCN/optical), which the roofline
accounts separately.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x (the pinned 0.4.37): no AxisType
    AxisType = None

from repro.models.layers import MeshCtx


import math


def compat_make_mesh(shape, axis_names, *, devices=None):
    """``jax.make_mesh`` across jax versions: pass ``axis_types`` when the
    installed jax supports it, fall back to a plain mesh otherwise."""
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axis_names, devices=devices,
                                 axis_types=(AxisType.Auto,) * len(axis_names))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axis_names, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()[:n]
    return compat_make_mesh(shape, axes, devices=devs)


def make_mesh_ctx(mesh) -> MeshCtx:
    names = mesh.axis_names
    if "pod" in names:
        return MeshCtx(mesh=mesh, dp=("pod", "data"), tp="model")
    return MeshCtx(mesh=mesh, dp=("data",), tp="model")


def make_host_mesh(dp: int = 1, tp: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return compat_make_mesh((dp, tp), ("data", "model"))


def make_data_mesh(ndev: int | None = None):
    """Pure data-parallel mesh for the sharded materializer: the first
    ``ndev`` (default: all) local devices on the "data" axis."""
    n = ndev if ndev is not None else len(jax.devices())
    return compat_make_mesh((n, 1), ("data", "model"))


def axis_size(mesh, axis) -> int:
    """Total device count along one mesh axis name or a tuple of names
    (the shard count of anything partitioned over ``axis``)."""
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]
    return n
