import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Only the dry-run forces 512 host devices.

import argparse
import json
import math
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as RL
from repro.configs.base import ARCHS, SHAPES, get_config, supported_cells
from repro.launch.mesh import make_mesh_ctx, make_production_mesh
from repro.models import model as M

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun")


def cell_id(arch, shape, multi_pod, tag=""):
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    sfx = f"-{tag}" if tag else ""
    return f"{arch}.{shape}.{mesh}{sfx}"


def run_glog_cell(multi_pod: bool, tag: str = "") -> dict:
    """Dry-run of the paper's own workload: ONE compiled TG round of the
    distributed executor (delta exchange + planned join + absorb) lowered
    on the production mesh.  (The executor is host-stepped — one such
    program runs per round — so this is the unit the mesh compiles.)"""
    from repro.engine.distributed import DistConfig, lower_distributed_tc
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    axis = ("pod", "data") if multi_pod else ("data",)
    cfg = DistConfig(shard_cap=1 << 20, delta_cap=1 << 18, bucket_cap=1 << 10,
                     axis=axis)
    lowered = lower_distributed_tc(mesh, cfg)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    per_dev = (mem.argument_size_in_bytes + mem.output_size_in_bytes
               + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rr = RL.analyze("glog_tc", "materialize", "2x16x16" if multi_pod else
                    "16x16", chips, cost, hlo, model_flops=0.0,
                    mem_stats=per_dev)
    return {"cell": cell_id("glog_tc", "materialize", multi_pod, tag),
            "arch": "glog_tc", "shape": "materialize",
            "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
            "status": "ok", "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {"per_device_total": per_dev,
                       "argument_bytes": mem.argument_size_in_bytes,
                       "temp_bytes": mem.temp_size_in_bytes,
                       "output_bytes": mem.output_size_in_bytes,
                       "alias_bytes": mem.alias_size_in_bytes},
            "roofline": rr.to_json()}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             tag: str = "", overrides=None) -> dict:
    if arch == "glog_tc":
        return run_glog_cell(multi_pod, tag)
    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mcx = make_mesh_ctx(mesh)
    chips = math.prod(mesh.devices.shape)
    mdl = M.build(cfg, mcx)

    ok, reason = supported_cells(cfg)[shape_name]
    rec = {"cell": cell_id(arch, shape_name, multi_pod, tag),
           "arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips}
    if not ok:
        rec.update({"status": "skipped", "reason": reason})
        return rec

    params_abs = mdl.abstract_params()
    params_sh = mdl.param_shardings()
    specs = mdl.input_specs(shape)
    repl = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            opt_abs = mdl.abstract_opt_state()
            opt_sh = mdl.opt_shardings()
            batch_sh = mdl.batch_shardings(specs["batch"])
            fn = jax.jit(
                mdl.train_step,
                in_shardings=(params_sh, opt_sh, batch_sh, repl),
                out_shardings=(params_sh, opt_sh, None),
                donate_argnums=(0, 1))
            lowered = fn.lower(params_abs, opt_abs, specs["batch"],
                               jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            batch_sh = mdl.batch_shardings(specs["batch"])
            cache_sh = mdl.cache_shardings(shape)
            tok_sh = mdl.batch_shardings(
                jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32))
            fn = jax.jit(mdl.prefill_step,
                         in_shardings=(params_sh, batch_sh),
                         out_shardings=(tok_sh, cache_sh))
            lowered = fn.lower(params_abs, specs["batch"])
        else:  # decode
            cache_sh = mdl.cache_shardings(shape)
            tok_sh = mdl.batch_shardings(specs["token"])
            out_tok_sh = mdl.batch_shardings(
                jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32))
            fn = jax.jit(mdl.decode_step,
                         in_shardings=(params_sh, cache_sh, tok_sh, repl),
                         out_shardings=(out_tok_sh, cache_sh),
                         donate_argnums=(1,))
            lowered = fn.lower(params_abs, specs["caches"], specs["token"],
                               specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    mf = RL.model_flops_estimate(cfg, shape)
    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    rr = RL.analyze(arch, shape_name, rec["mesh"], chips, cost, hlo, mf,
                    mem_stats=per_dev_bytes)
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": per_dev_bytes,
        },
        "roofline": rr.to_json(),
    })
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true",
                    help="run every (arch x shape x mesh) cell in "
                         "subprocesses; resumable")
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", default="",
                    help="comma-separated cfg overrides k=v (perf experiments)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.sweep:
        cells = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
        for arch, shape, mp in cells:
            cid = cell_id(arch, shape, mp, args.tag)
            path = os.path.join(args.out, cid + ".json")
            if os.path.exists(path):
                print(f"[skip] {cid}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", args.out]
            if mp:
                cmd.append("--multi-pod")
            if args.tag:
                cmd += ["--tag", args.tag]
            if args.override:
                cmd += ["--override", args.override]
            print(f"[run ] {cid}", flush=True)
            r = subprocess.run(cmd)
            if r.returncode != 0:
                with open(path, "w") as f:
                    json.dump({"cell": cid, "status": "error",
                               "returncode": r.returncode}, f)
        return

    overrides = {}
    if args.override:
        for kv in args.override.split(","):
            k, v = kv.split("=")
            try:
                v = json.loads(v)
            except Exception:
                pass
            overrides[k] = v

    cid = cell_id(args.arch, args.shape, args.multi_pod, args.tag)
    path = os.path.join(args.out, cid + ".json")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.out,
                       args.tag, overrides or None)
    except Exception as e:
        rec = {"cell": cid, "status": "error", "error": repr(e),
               "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    status = rec.get("status")
    print(f"{cid}: {status}")
    if status == "ok":
        r = rec["roofline"]
        print(f"  compute={r['compute_s']:.4g}s memory={r['memory_s']:.4g}s "
              f"collective={r['collective_s']:.4g}s bottleneck={r['bottleneck']}"
              f" useful={r['useful_ratio']:.3f} "
              f"mem/dev={rec['memory']['per_device_total']/1e9:.2f}GB")
    elif status == "error":
        print(rec.get("traceback", "")[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
