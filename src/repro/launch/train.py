"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Builds the mesh (or a 512-device simulated production mesh with
``--simulate-pod``), the model for the selected architecture, the data
pipeline (synthetic tokens or a KB-linearized stream), and runs the fault-
tolerant training loop (checkpoint/resume/preemption).

On a real TPU slice, run the same module under your process launcher; the
mesh builder picks up all visible devices.  Recommended XLA flags for
overlap (latency-hiding scheduler) are appended when --tpu-flags is set.
"""
import os
import sys

if "--simulate-pod" in sys.argv:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if "--tpu-flags" in sys.argv:
    os.environ["LIBTPU_INIT_ARGS"] = os.environ.get(
        "LIBTPU_INIT_ARGS", "") + " --xla_enable_async_collective_permute=true"
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
        " --xla_tpu_enable_latency_hiding_scheduler=true"

import argparse

import jax

from repro.configs.base import get_config, get_smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_mesh_ctx, make_production_mesh, \
    make_host_mesh
from repro.models import model as M
from repro.train.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--simulate-pod", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--tpu-flags", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.simulate_pod:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_host_mesh(dp=1, tp=jax.device_count())
    mcx = make_mesh_ctx(mesh)
    mdl = M.build(cfg, mcx)
    n = cfg.param_counts()["total"]
    print(f"[launch] arch={cfg.name} params={n/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} devices={mesh.devices.size}")
    data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq)
    train(mdl, data, steps=args.steps, ckpt_dir=args.ckpt,
          ckpt_every=args.ckpt_every)


if __name__ == "__main__":
    main()
