"""Version-compat shims for the pinned jax (0.4.37).

The codebase targets the modern jax API surface; this module backfills the
symbols that moved after 0.4.x so the same call sites work on both:

* ``shard_map`` — top-level ``jax.shard_map`` vs
  ``jax.experimental.shard_map.shard_map`` (same signature for the subset we
  use: ``f, mesh=, in_specs=, out_specs=``).

Mesh-construction compat (``AxisType``) lives in ``repro.launch.mesh``.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *args, **kwargs):
        # 0.4.x's replication checker has no rule for `while` (used by the
        # distributed fixpoint loop); later jax removed the check entirely,
        # so match that behavior unless the caller asks for it
        kwargs.setdefault("check_rep", False)
        return _shard_map_04(f, *args, **kwargs)
