"""Dictionary encoding of constants/nulls to narrow integer ids (GLog
stores terms via Trident's dictionary; we do the same at ingest).

Ids:
* constants: 0 .. n-1 (interned terms)
* skolem nulls: negative ids, allocated per (rule, exvar, frontier tuple) —
  matching the skolem chase the engine implements for existential rules.

Null id ``-k`` decodes to the dedicated ``Null(k)`` sentinel (never to a
string), so a genuine constant that happens to be named like a null (e.g.
``"_sk1"``) can never collide with a labelled null: ``decode`` is injective
over all allocated ids and ``encode(decode(i)) == i`` for every id the
dictionary has handed out.

Id dtype
--------
The dictionary is bound to a store dtype (default: the process
``REPRO_STORE_DTYPE``) and enforces its id range *at ingest*: the dtype's
max value is the engine's PAD sentinel and is never handed out, and an
``OverflowError`` is raised the moment an id (constant or null) would leave
the representable range — ids that silently wrap would corrupt sort keys
downstream, which is strictly worse than failing the load.

Bulk ingest
-----------
``encode_columns`` vectorizes interning over ndarray columns with one
``np.unique`` pass: the python-level dict lookup runs once per *distinct*
term, not once per occurrence — the difference between the ingest loop and
the engine being the bottleneck at 10^7+ facts.  ``encode_many`` routes
large batches through it automatically.

Integer terms never touch the python dict at all: they live in a pair of
sorted numpy arrays (value-sorted for interning via ``searchsorted``,
id-sorted for ``decode``), so a 10^7-row all-integer stream costs a few
numpy merges and ~16 bytes per distinct term instead of ~100+ bytes of
CPython dict/object overhead per term — at scale the dictionary would
otherwise dominate peak RSS regardless of the store dtype.  Routing is by
*value*, not input dtype: a python ``int``, a ``np.int32`` scalar and an
object-array cell holding the same value all intern to the same id (ints
too wide for int64 fall back to the generic dict store).
"""
from __future__ import annotations

from typing import Dict, Hashable

import numpy as np

from repro.core.terms import Null
from repro.engine.relation import id_range, store_dtype

# encode_many batches at least this long take the vectorized np.unique path
_BULK_THRESHOLD = 64


class Dictionary:
    def __init__(self, id_dtype=None):
        self.id_dtype = (np.dtype(id_dtype) if id_dtype is not None
                         else store_dtype())
        self._min_id, self._max_id = id_range(self.id_dtype)
        self._n_terms = 0                       # total ids handed out
        self._to_id: Dict[Hashable, int] = {}   # non-integer term -> id
        self._from_id: Dict[int, Hashable] = {}  # id -> non-integer term
        # integer-term store: (_int_vals, _int_ids) sorted by value for
        # interning, (_dec_ids, _dec_vals) sorted by id for decode (ids grow
        # monotonically, so per-batch appends keep it sorted)
        self._int_vals = np.empty(0, np.int64)
        self._int_ids = np.empty(0, np.int64)
        self._dec_ids = np.empty(0, np.int64)
        self._dec_vals = np.empty(0, np.int64)
        self._skolem: Dict[tuple, int] = {}
        self._next_null = -1

    def _check_capacity(self, needed_max: int) -> None:
        if needed_max > self._max_id:
            raise OverflowError(
                f"dictionary id {needed_max} exceeds the {self.id_dtype} "
                f"store id range [0, {self._max_id}] (PAD is reserved); "
                "use a wider REPRO_STORE_DTYPE")

    def _intern_ints_unique(self, uniq: np.ndarray) -> np.ndarray:
        """ids for a SORTED-UNIQUE int64 value array, interning new values.
        Batch-checks capacity before mutating anything."""
        n = len(self._int_vals)
        pos = np.searchsorted(self._int_vals, uniq)
        if n:
            safe = np.minimum(pos, n - 1)
            known = (pos < n) & (self._int_vals[safe] == uniq)
        else:
            known = np.zeros(len(uniq), dtype=bool)
        ids = np.empty(len(uniq), np.int64)
        if known.any():
            ids[known] = self._int_ids[pos[known]]
        new = ~known
        n_new = int(new.sum())
        if n_new:
            self._check_capacity(self._n_terms + n_new - 1)
            new_ids = np.arange(self._n_terms, self._n_terms + n_new,
                                dtype=np.int64)
            ids[new] = new_ids
            new_vals = uniq[new]
            self._int_vals = np.insert(self._int_vals, pos[new], new_vals)
            self._int_ids = np.insert(self._int_ids, pos[new], new_ids)
            self._dec_ids = np.concatenate([self._dec_ids, new_ids])
            self._dec_vals = np.concatenate([self._dec_vals, new_vals])
            self._n_terms += n_new
        return ids

    def encode(self, term) -> int:
        if isinstance(term, Null):
            # only engine-allocated nulls round-trip; a fabricated Null id
            # could collide with a future skolem allocation
            if not 1 <= term.nid <= self.num_nulls:
                raise ValueError(f"unknown null {term!r}: nulls are allocated "
                                 "by Dictionary.skolem, not encoded from the "
                                 "outside")
            return -term.nid
        if isinstance(term, (int, np.integer)):
            try:
                v = np.int64(term)
            except (OverflowError, ValueError):
                pass    # wider than int64: generic store below
            else:
                return int(self._intern_ints_unique(
                    np.asarray([v], np.int64))[0])
        i = self._to_id.get(term)
        if i is None:
            i = self._n_terms
            self._check_capacity(i)
            self._to_id[term] = i
            self._from_id[i] = term
            self._n_terms += 1
        return i

    def encode_many(self, terms):
        terms = list(terms)
        if len(terms) >= _BULK_THRESHOLD and not any(
                isinstance(t, Null) for t in terms):
            # build the object array explicitly: np.asarray would splat a
            # list of equal-length tuples into a 2D array, interning tuple
            # *elements* instead of the tuple terms themselves
            arr = np.empty((len(terms), 1), dtype=object)
            arr[:, 0] = terms
            try:
                return [int(x) for x in self.encode_columns(arr)[:, 0]]
            except (TypeError, ValueError):
                # unorderable mixed terms (or ragged tuples np.unique can't
                # compare): per-term fallback
                pass
        return [self.encode(t) for t in terms]

    def encode_columns(self, rows: np.ndarray) -> np.ndarray:
        """Vectorized interning of an (n, arity) ndarray of terms (strings,
        ints, ... — any hashable, orderable scalars) into an (n, arity) id
        array of the dictionary's dtype.  One ``np.unique`` over the flat
        terms; per-distinct-term work only (and pure numpy for integer
        input).  Raises ``OverflowError`` before returning ids if interning
        would leave the dtype's id range."""
        rows = np.asarray(rows)
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        n, ar = rows.shape
        if n == 0:
            return np.zeros((0, ar), self.id_dtype)
        flat = rows.reshape(-1)
        if flat.dtype.kind in "iub":
            if (flat.dtype.kind == "u" and flat.size
                    and int(flat.max()) > np.iinfo(np.int64).max):
                # uint64 values past int64 max would wrap under astype;
                # demote to python ints on the object path, which routes
                # over-wide ints to the generic store (same as encode())
                demoted = np.empty(flat.shape, dtype=object)
                demoted[:] = [int(v) for v in flat]
                flat = demoted
            else:
                uniq, inv = np.unique(flat.astype(np.int64),
                                      return_inverse=True)
                ids = self._intern_ints_unique(uniq)
                return ids[inv].reshape(n, ar).astype(self.id_dtype)
        uniq, inv = np.unique(flat, return_inverse=True)
        terms = uniq.tolist()
        is_int = [isinstance(t, (int, np.integer)) for t in terms]
        if all(is_int):
            try:
                vals = np.asarray(terms, np.int64)
            except (OverflowError, ValueError):
                pass    # some term wider than int64: mixed path below
            else:
                ids = self._intern_ints_unique(vals)
                return ids[inv].reshape(n, ar).astype(self.id_dtype)
        if any(is_int):
            # mixed batch (e.g. ints + floats): per-term routing keeps each
            # value in one store; rare enough that the loop is fine
            known = [self.encode(t) for t in terms]
            ids = np.asarray(known, dtype=np.int64)[inv].reshape(n, ar)
            return ids.astype(self.id_dtype)
        get = self._to_id.get
        known = [get(t) for t in terms]
        n_new = sum(1 for i in known if i is None)
        if n_new:
            # range-check the whole batch BEFORE interning anything: a
            # partial batch would hand out ids the caller never sees
            self._check_capacity(self._n_terms + n_new - 1)
            nxt = self._n_terms
            for k, (t, i) in enumerate(zip(terms, known)):
                if i is None:
                    known[k] = self._to_id[t] = nxt
                    self._from_id[nxt] = t
                    nxt += 1
            self._n_terms = nxt
        ids = np.asarray(known, dtype=np.int64)[inv].reshape(n, ar)
        return ids.astype(self.id_dtype)

    def decode(self, i: int):
        if i < 0:
            return Null(-i)
        term = self._from_id.get(i)
        if term is not None:
            return term
        j = int(np.searchsorted(self._dec_ids, i))
        if j < len(self._dec_ids) and self._dec_ids[j] == i:
            return int(self._dec_vals[j])
        raise IndexError(f"unknown dictionary id {i}")

    def skolem(self, key: tuple) -> int:
        i = self._skolem.get(key)
        if i is None:
            i = self._next_null
            if i < self._min_id:
                raise OverflowError(
                    f"skolem null id {i} exceeds the {self.id_dtype} store "
                    f"id range [{self._min_id}, -1]; use a wider "
                    "REPRO_STORE_DTYPE")
            self._next_null -= 1
            self._skolem[key] = i
        return i

    def __len__(self):
        return self._n_terms

    @property
    def num_nulls(self):
        return -self._next_null - 1

    # -- transactional ingest / checkpointing -------------------------------
    def mark(self) -> tuple:
        """O(1) rollback token for transactional ingest.  Ids grow
        monotonically and the integer-store numpy arrays are *replaced* on
        growth (never mutated in place), so holding the current array
        references plus the two counters freezes this state."""
        return (self._n_terms, self._next_null, self._int_vals,
                self._int_ids, self._dec_ids, self._dec_vals)

    def rollback(self, token: tuple) -> None:
        """Discard every id handed out since ``mark()`` returned ``token``
        (a failed ingest chunk must not leave half-interned terms behind:
        later chunks would otherwise intern around ghosts whose ids no
        store row references)."""
        n_terms, next_null, iv, ii, di, dv = token
        for t, i in [kv for kv in self._to_id.items() if kv[1] >= n_terms]:
            del self._to_id[t]
            del self._from_id[i]
        for k in [k for k, i in self._skolem.items() if i <= next_null]:
            del self._skolem[k]
        self._n_terms = n_terms
        self._next_null = next_null
        self._int_vals, self._int_ids = iv, ii
        self._dec_ids, self._dec_vals = di, dv

    def state_dict(self) -> dict:
        """Picklable snapshot of the full interning state (what the engine
        checkpoints next to the stores: encoded rows are meaningless
        without the exact id assignment that produced them)."""
        return {
            "version": 1,
            "id_dtype": self.id_dtype.str,
            "n_terms": self._n_terms,
            "next_null": self._next_null,
            "to_id": dict(self._to_id),
            "skolem": dict(self._skolem),
            "int_vals": self._int_vals.copy(),
            "int_ids": self._int_ids.copy(),
            "dec_ids": self._dec_ids.copy(),
            "dec_vals": self._dec_vals.copy(),
        }

    def load_state(self, state: dict) -> None:
        """Restore a ``state_dict()`` snapshot in place (references to this
        Dictionary stay valid).  The dtype must match: ids encoded under a
        different store dtype would not round-trip the PAD reservation."""
        if np.dtype(state["id_dtype"]) != self.id_dtype:
            raise ValueError(
                f"checkpointed dictionary dtype {state['id_dtype']} does "
                f"not match this process's {self.id_dtype} "
                "(REPRO_STORE_DTYPE changed between save and restore)")
        self._n_terms = int(state["n_terms"])
        self._next_null = int(state["next_null"])
        self._to_id = dict(state["to_id"])
        self._from_id = {i: t for t, i in self._to_id.items()}
        self._skolem = dict(state["skolem"])
        self._int_vals = np.asarray(state["int_vals"], np.int64)
        self._int_ids = np.asarray(state["int_ids"], np.int64)
        self._dec_ids = np.asarray(state["dec_ids"], np.int64)
        self._dec_vals = np.asarray(state["dec_vals"], np.int64)
