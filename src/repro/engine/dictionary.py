"""Dictionary encoding of constants/nulls to int32 ids (GLog stores terms via
Trident's dictionary; we do the same at ingest).

Ids:
* constants: 0 .. n-1 (interned strings)
* skolem nulls: negative ids, allocated per (rule, exvar, frontier tuple) —
  matching the skolem chase the engine implements for existential rules.

Null id ``-k`` decodes to the dedicated ``Null(k)`` sentinel (never to a
string), so a genuine constant that happens to be named like a null (e.g.
``"_sk1"``) can never collide with a labelled null: ``decode`` is injective
over all allocated ids and ``encode(decode(i)) == i`` for every id the
dictionary has handed out.
"""
from __future__ import annotations

from typing import Dict, Hashable, List

from repro.core.terms import Null


class Dictionary:
    def __init__(self):
        self._to_id: Dict[Hashable, int] = {}
        self._from_id: List[Hashable] = []
        self._skolem: Dict[tuple, int] = {}
        self._next_null = -1

    def encode(self, term) -> int:
        if isinstance(term, Null):
            # only engine-allocated nulls round-trip; a fabricated Null id
            # could collide with a future skolem allocation
            if not 1 <= term.nid <= self.num_nulls:
                raise ValueError(f"unknown null {term!r}: nulls are allocated "
                                 "by Dictionary.skolem, not encoded from the "
                                 "outside")
            return -term.nid
        i = self._to_id.get(term)
        if i is None:
            i = len(self._from_id)
            self._to_id[term] = i
            self._from_id.append(term)
        return i

    def encode_many(self, terms):
        return [self.encode(t) for t in terms]

    def decode(self, i: int):
        if i < 0:
            return Null(-i)
        return self._from_id[i]

    def skolem(self, key: tuple) -> int:
        i = self._skolem.get(key)
        if i is None:
            i = self._next_null
            self._next_null -= 1
            self._skolem[key] = i
        return i

    def __len__(self):
        return len(self._from_id)

    @property
    def num_nulls(self):
        return -self._next_null - 1
