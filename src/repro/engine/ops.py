"""Vectorized relational operators on padded int32 relations (pure jnp).

All functions are shape-stable and jit-cached per capacity bucket.  Data-
dependent sizes follow the two-phase pattern: a jitted *count* pass, a host
pow-2 bucket choice, then a jitted *materialize* pass.

The sort/dedup/probe inner loops have Pallas TPU kernels in
``repro.kernels`` (used when ``repro.kernels.ops.USE_PALLAS`` is on); these
jnp versions are the reference implementations and the CPU path.
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.relation import PAD, Relation, next_pow2


# ---------------------------------------------------------------------------
# sorting / dedup
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _lexsort_fn(cap, ar):
    @jax.jit
    def f(data):
        keys = tuple(data[:, c] for c in reversed(range(ar)))
        order = jnp.lexsort(keys)
        return data[order]
    return f


def lexsort_rows(rel: Relation) -> Relation:
    return Relation(_lexsort_fn(rel.capacity, rel.arity)(rel.data), rel.count)


@lru_cache(maxsize=None)
def _dedup_count_fn(cap, ar):
    @jax.jit
    def f(sorted_data):
        prev = jnp.roll(sorted_data, 1, axis=0)
        neq = jnp.any(sorted_data != prev, axis=1)
        neq = neq.at[0].set(True)
        valid = sorted_data[:, 0] != PAD
        return jnp.sum(jnp.logical_and(neq, valid)), jnp.logical_and(neq, valid)
    return f


@lru_cache(maxsize=None)
def _compact_fn(cap, ar, out_cap):
    @jax.jit
    def f(data, mask):
        pos = jnp.cumsum(mask) - 1
        idx = jnp.where(mask, pos, out_cap)
        out = jnp.full((out_cap + 1, ar), PAD, jnp.int32)
        out = out.at[idx].set(data, mode="drop")
        return out[:out_cap]
    return f


def dedup(rel: Relation) -> Relation:
    """Sort + adjacent-unique + compact."""
    if rel.count == 0:
        return Relation.empty(rel.arity)
    s = lexsort_rows(rel)
    n, mask = _dedup_count_fn(rel.capacity, rel.arity)(s.data)
    n = int(n)
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(s.data, mask)
    return Relation(out, n)


# ---------------------------------------------------------------------------
# filters / projection
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _filter_count_fn(cap, ar, eq_pairs, const_pairs):
    @jax.jit
    def f(data):
        valid = data[:, 0] != PAD
        for a, b in eq_pairs:
            valid &= data[:, a] == data[:, b]
        for c, v in const_pairs:
            valid &= data[:, c] == v
        return jnp.sum(valid), valid
    return f


def filter_rows(rel: Relation, eq_pairs=(), const_pairs=()) -> Relation:
    """Select rows with col equality (repeated vars) / constant constraints."""
    if rel.count == 0 or (not eq_pairs and not const_pairs):
        return rel
    n, mask = _filter_count_fn(rel.capacity, rel.arity, tuple(eq_pairs),
                               tuple(const_pairs))(rel.data)
    n = int(n)
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, mask)
    return Relation(out, n)


@lru_cache(maxsize=None)
def _project_fn(cap, ar, cols):
    @jax.jit
    def f(data):
        valid = data[:, 0] != PAD
        out = data[:, jnp.array(cols, jnp.int32)]
        return jnp.where(valid[:, None], out, PAD)
    return f


def project(rel: Relation, cols) -> Relation:
    if not cols:
        cols = (0,)
    return Relation(_project_fn(rel.capacity, rel.arity, tuple(cols))(rel.data),
                    rel.count)


# ---------------------------------------------------------------------------
# sort-merge join (single int32 key column; multi-column keys are packed by
# the planner with post-join verification)
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _sortby_fn(cap, ar, key_col):
    @jax.jit
    def f(data):
        order = jnp.argsort(data[:, key_col])
        return data[order]
    return f


def sort_by(rel: Relation, key_col: int) -> Relation:
    return Relation(_sortby_fn(rel.capacity, rel.arity, key_col)(rel.data),
                    rel.count)


@lru_cache(maxsize=None)
def _join_count_fn(lcap, lar, rcap, rar, lkey, rkey):
    @jax.jit
    def f(l, r):
        lk = l[:, lkey]
        rk = r[:, rkey]
        lo = jnp.searchsorted(rk, lk, side="left")
        hi = jnp.searchsorted(rk, lk, side="right")
        valid = lk != PAD
        per = jnp.where(valid, hi - lo, 0)
        cum = jnp.cumsum(per) - per           # exclusive prefix
        return jnp.sum(per), per, cum, lo
    return f


@lru_cache(maxsize=None)
def _join_mat_fn(lcap, lar, rcap, rar, out_cap):
    @jax.jit
    def f(l, r, per, cum, lo, total):
        t = jnp.arange(out_cap)
        # left row for output t: last i with cum[i] <= t
        i = jnp.searchsorted(cum + per, t, side="right")
        i = jnp.clip(i, 0, lcap - 1)
        j = lo[i] + (t - cum[i])
        j = jnp.clip(j, 0, rcap - 1)
        valid = t < total
        lrow = l[i]
        rrow = r[j]
        out = jnp.concatenate([lrow, rrow], axis=1)
        return jnp.where(valid[:, None], out, PAD)
    return f


def sm_join(l: Relation, r: Relation, lkey: int, rkey: int):
    """Sort-merge join; returns (Relation out, matches) where out columns are
    [l cols..., r cols...] and ``matches`` is the trigger count."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity), 0
    ls = sort_by(l, lkey)
    rs = sort_by(r, rkey)
    total, per, cum, lo = _join_count_fn(
        l.capacity, l.arity, r.capacity, r.arity, lkey, rkey)(ls.data, rs.data)
    total = int(total)
    if total == 0:
        return Relation.empty(l.arity + r.arity), 0
    out_cap = next_pow2(total)
    out = _join_mat_fn(l.capacity, l.arity, r.capacity, r.arity, out_cap)(
        ls.data, rs.data, per, cum, lo, total)
    return Relation(out, total), total


def cross(l: Relation, r: Relation):
    """Cartesian product (rare in practice; needed for disconnected bodies)."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity), 0
    total = l.count * r.count
    out_cap = next_pow2(total)
    li = jnp.repeat(jnp.arange(l.count), r.count, total_repeat_length=total)
    ri = jnp.tile(jnp.arange(r.count), l.count)[:total]
    out = jnp.full((out_cap, l.arity + r.arity), PAD, jnp.int32)
    rows = jnp.concatenate([l.data[li], r.data[ri]], axis=1)
    out = jax.lax.dynamic_update_slice(out, rows, (0, 0))
    return Relation(out, total), total


# ---------------------------------------------------------------------------
# antijoin (Def. 23 / redundancy filtering): drop rows whose key-tuple occurs
# in a sorted haystack relation
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _anti_count_fn(cap, ar, hcap, har, cols):
    @jax.jit
    def f(data, hay_sorted):
        # compare on all haystack columns: hay is the full (har)-tuple set;
        # probe tuple built from data[:, cols]
        probe = data[:, jnp.array(cols, jnp.int32)]
        # lexicographic binary search via packed comparison per column chain:
        # search on first col, then verify with scan over candidates is not
        # shape-stable; instead: since haystack rows are lexsorted, use
        # searchsorted over a fused comparison by iterating columns.
        n = hay_sorted.shape[0]
        lo = jnp.zeros(probe.shape[0], jnp.int32)
        hi = jnp.full(probe.shape[0], n, jnp.int32)
        for c in range(har):
            col = hay_sorted[:, c]
            key = probe[:, c]
            # narrow [lo, hi) to rows where col == key using vectorized
            # searchsorted on the global sorted column is invalid; use
            # per-row binary search instead
            lo, hi = _range_narrow(col, key, lo, hi)
        found = hi > lo
        valid = data[:, 0] != PAD
        keep = jnp.logical_and(valid, jnp.logical_not(found))
        return jnp.sum(keep), keep
    return f


def _range_narrow(col, key, lo, hi):
    """Per-row binary search narrowing [lo,hi) to col==key (col sorted within
    each [lo,hi) range by lexsort invariant)."""
    n = col.shape[0]
    steps = max(1, int(np.ceil(np.log2(n + 1))))

    def bs(side):
        l, h = lo, hi
        for _ in range(steps):
            mid = (l + h) // 2
            v = col[jnp.clip(mid, 0, n - 1)]
            go_right = jnp.where(side == 0, v < key, v <= key)
            l = jnp.where(jnp.logical_and(mid < h, go_right), mid + 1, l)
            h = jnp.where(jnp.logical_and(mid < h, jnp.logical_not(go_right)),
                          mid, h)
        return l

    new_lo = bs(jnp.array(0))
    new_hi = bs(jnp.array(1))
    return new_lo, new_hi


def antijoin(rel: Relation, hay: Relation, cols=None) -> Relation:
    """Rows of rel whose ``cols``-tuple is NOT in hay (hay lexsorted)."""
    if rel.count == 0:
        return rel
    if hay.count == 0:
        return rel
    cols = tuple(cols) if cols is not None else tuple(range(rel.arity))
    assert len(cols) == hay.arity
    hs = lexsort_rows(hay)
    n, keep = _anti_count_fn(rel.capacity, rel.arity, hay.capacity, hay.arity,
                             cols)(rel.data, hs.data)
    n = int(n)
    if n == rel.count:
        return rel
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, keep)
    return Relation(out, n)


# ---------------------------------------------------------------------------
# union / append
# ---------------------------------------------------------------------------
def union(a: Relation, b: Relation, dedupe: bool = True) -> Relation:
    if a.count == 0:
        return b
    if b.count == 0:
        return a
    n = a.count + b.count
    cap = next_pow2(n)
    data = jnp.full((cap, a.arity), PAD, jnp.int32)
    data = jax.lax.dynamic_update_slice(data, a.data[:a.count], (0, 0))
    data = jax.lax.dynamic_update_slice(data, b.data[:b.count], (a.count, 0))
    out = Relation(data, n)
    return dedup(out) if dedupe else out
