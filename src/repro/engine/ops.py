"""Vectorized relational operators on padded int32 relations.

All functions are shape-stable and jit-cached per capacity bucket.  Data-
dependent sizes follow the two-phase pattern: a jitted *count* pass, a host
pow-2 bucket choice, then a jitted *materialize* pass.

Sortedness invariant
--------------------
Operators honor the ``Relation.sorted_by`` marker: ``dedup``/``antijoin``/
``sm_join`` skip their sort pass when an input already carries the needed
order, and ``merge_union`` folds a small sorted delta into a sorted store
with two lexicographic binary-search passes instead of a concat-and-resort
(O((m+n)·ar·log) vs O((m+n)·log(m+n)) full sort work per call — and, more
importantly, no re-sorting of the already-sorted store).  ``SORT_STATS``
counts performed vs skipped sort passes; ``REPRO_SORTED_STORE=0`` disables
the fast paths for A/B benchmarking.

Kernel dispatch
---------------
Setting ``REPRO_USE_PALLAS=1`` routes the sort / unique-mask / membership-
probe inner loops through the Pallas kernels in ``repro.kernels.ops``
(``sort_with_payload``, ``unique_mask``, ``probe_sorted``; interpret mode on
CPU, compiled on TPU).  The jnp implementations here are the reference path
and the default.  Multi-column lexsorts and the merge-union binary searches
stay on the jnp path in both modes (the kernels are single-key).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.relation import PAD, Relation, lex_order, next_pow2


# ---------------------------------------------------------------------------
# dispatch switches + sort-pass accounting
# ---------------------------------------------------------------------------
def use_pallas() -> bool:
    """Route sort/unique/probe inner loops through the Pallas kernels."""
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def sorted_store_enabled() -> bool:
    """Honor ``sorted_by`` markers (skip redundant sorts, merge unions)."""
    return os.environ.get("REPRO_SORTED_STORE", "1") != "0"


_KERNELS = None


def _kernels():
    global _KERNELS
    if _KERNELS is None:
        from repro.kernels import ops as _ko
        _KERNELS = _ko
    return _KERNELS


@dataclass
class SortStats:
    """Counts of sort passes performed / avoided (the paper's redundant-work
    argument, applied to the engine's own hot path)."""
    lexsort: int = 0       # full row lexsorts executed
    key_sort: int = 0      # single-key sorts executed (sm_join inputs)
    merges: int = 0        # incremental merge-unions executed
    skipped: int = 0       # sort passes avoided via a sorted_by marker

    def reset(self):
        self.lexsort = self.key_sort = self.merges = self.skipped = 0

    def total_sorts(self) -> int:
        return self.lexsort + self.key_sort


SORT_STATS = SortStats()


# ---------------------------------------------------------------------------
# sorting / dedup
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _lexsort_fn(cap, ar):
    @jax.jit
    def f(data):
        keys = tuple(data[:, c] for c in reversed(range(ar)))
        order = jnp.lexsort(keys)
        return data[order]
    return f


@lru_cache(maxsize=None)
def _keysort_pallas_fn(cap, ar, key_col):
    K = _kernels()
    tile = min(1024, cap)

    @jax.jit
    def f(data):
        keys = data[:, key_col]
        vals = jnp.arange(cap, dtype=jnp.int32)
        _, perm = K.sort_with_payload(keys, vals, tile=tile)
        return data[perm]
    return f


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


def lexsort_rows(rel: Relation) -> Relation:
    order = lex_order(rel.arity)
    if sorted_store_enabled() and rel.sorted_by == order:
        SORT_STATS.skipped += 1
        return rel
    if use_pallas() and rel.arity == 1 and _is_pow2(rel.capacity):
        data = _keysort_pallas_fn(rel.capacity, 1, 0)(rel.data)
    else:
        data = _lexsort_fn(rel.capacity, rel.arity)(rel.data)
    SORT_STATS.lexsort += 1
    return Relation(data, rel.count, order)


@lru_cache(maxsize=None)
def _dedup_count_fn(cap, ar):
    @jax.jit
    def f(sorted_data):
        prev = jnp.roll(sorted_data, 1, axis=0)
        neq = jnp.any(sorted_data != prev, axis=1)
        neq = neq.at[0].set(True)
        valid = sorted_data[:, 0] != PAD
        return jnp.sum(jnp.logical_and(neq, valid)), jnp.logical_and(neq, valid)
    return f


@lru_cache(maxsize=None)
def _dedup_count_pallas_fn(cap, ar):
    K = _kernels()

    @jax.jit
    def f(sorted_data):
        mask = K.unique_mask(sorted_data).astype(bool)
        return jnp.sum(mask), mask
    return f


@lru_cache(maxsize=None)
def _compact_fn(cap, ar, out_cap):
    @jax.jit
    def f(data, mask):
        pos = jnp.cumsum(mask) - 1
        idx = jnp.where(mask, pos, out_cap)
        out = jnp.full((out_cap + 1, ar), PAD, jnp.int32)
        out = out.at[idx].set(data, mode="drop")
        return out[:out_cap]
    return f


def dedup(rel: Relation) -> Relation:
    """Sort (skipped on a lexsorted input) + adjacent-unique + compact.
    Output is lexsorted and marked."""
    if rel.count == 0:
        return Relation.empty(rel.arity)
    s = lexsort_rows(rel)
    if use_pallas():
        n, mask = _dedup_count_pallas_fn(s.capacity, s.arity)(s.data)
    else:
        n, mask = _dedup_count_fn(s.capacity, s.arity)(s.data)
    n = int(n)
    cap = next_pow2(n)
    out = _compact_fn(s.capacity, s.arity, cap)(s.data, mask)
    return Relation(out, n, lex_order(rel.arity))


# ---------------------------------------------------------------------------
# filters / projection
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _filter_count_fn(cap, ar, eq_pairs, const_pairs):
    @jax.jit
    def f(data):
        valid = data[:, 0] != PAD
        for a, b in eq_pairs:
            valid &= data[:, a] == data[:, b]
        for c, v in const_pairs:
            valid &= data[:, c] == v
        return jnp.sum(valid), valid
    return f


def filter_rows(rel: Relation, eq_pairs=(), const_pairs=()) -> Relation:
    """Select rows with col equality (repeated vars) / constant constraints.
    Compaction keeps row order, so the sortedness marker is preserved."""
    if rel.count == 0 or (not eq_pairs and not const_pairs):
        return rel
    n, mask = _filter_count_fn(rel.capacity, rel.arity, tuple(eq_pairs),
                               tuple(const_pairs))(rel.data)
    n = int(n)
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, mask)
    return Relation(out, n, rel.sorted_by)


@lru_cache(maxsize=None)
def _project_fn(cap, ar, cols):
    @jax.jit
    def f(data):
        valid = data[:, 0] != PAD
        out = data[:, jnp.array(cols, jnp.int32)]
        return jnp.where(valid[:, None], out, PAD)
    return f


def project(rel: Relation, cols) -> Relation:
    if not cols:
        cols = (0,)
    return Relation(_project_fn(rel.capacity, rel.arity, tuple(cols))(rel.data),
                    rel.count)


# ---------------------------------------------------------------------------
# sort-merge join (single int32 key column; multi-column keys are packed by
# the planner with post-join verification)
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _sortby_fn(cap, ar, key_col):
    @jax.jit
    def f(data):
        order = jnp.argsort(data[:, key_col])
        return data[order]
    return f


def sort_by(rel: Relation, key_col: int) -> Relation:
    """Sort by one key column; skipped when ``sorted_by`` already starts with
    that column (a lexsorted relation is sorted by its primary column)."""
    if (sorted_store_enabled() and rel.sorted_by
            and rel.sorted_by[0] == key_col):
        SORT_STATS.skipped += 1
        return rel
    if use_pallas() and _is_pow2(rel.capacity):
        data = _keysort_pallas_fn(rel.capacity, rel.arity, key_col)(rel.data)
    else:
        data = _sortby_fn(rel.capacity, rel.arity, key_col)(rel.data)
    SORT_STATS.key_sort += 1
    return Relation(data, rel.count, (key_col,))


@lru_cache(maxsize=None)
def _join_count_fn(lcap, lar, rcap, rar, lkey, rkey):
    @jax.jit
    def f(l, r):
        lk = l[:, lkey]
        rk = r[:, rkey]
        lo = jnp.searchsorted(rk, lk, side="left")
        hi = jnp.searchsorted(rk, lk, side="right")
        valid = lk != PAD
        per = jnp.where(valid, hi - lo, 0)
        cum = jnp.cumsum(per) - per           # exclusive prefix
        return jnp.sum(per), per, cum, lo
    return f


@lru_cache(maxsize=None)
def _join_mat_fn(lcap, lar, rcap, rar, out_cap):
    @jax.jit
    def f(l, r, per, cum, lo, total):
        t = jnp.arange(out_cap)
        # left row for output t: last i with cum[i] <= t
        i = jnp.searchsorted(cum + per, t, side="right")
        i = jnp.clip(i, 0, lcap - 1)
        j = lo[i] + (t - cum[i])
        j = jnp.clip(j, 0, rcap - 1)
        valid = t < total
        lrow = l[i]
        rrow = r[j]
        out = jnp.concatenate([lrow, rrow], axis=1)
        return jnp.where(valid[:, None], out, PAD)
    return f


def sm_join(l: Relation, r: Relation, lkey: int, rkey: int):
    """Sort-merge join; returns (Relation out, matches) where out columns are
    [l cols..., r cols...] and ``matches`` is the trigger count.  Input sorts
    are skipped for relations already sorted by their join key."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity), 0
    ls = sort_by(l, lkey)
    rs = sort_by(r, rkey)
    total, per, cum, lo = _join_count_fn(
        l.capacity, l.arity, r.capacity, r.arity, lkey, rkey)(ls.data, rs.data)
    total = int(total)
    if total == 0:
        return Relation.empty(l.arity + r.arity), 0
    out_cap = next_pow2(total)
    out = _join_mat_fn(l.capacity, l.arity, r.capacity, r.arity, out_cap)(
        ls.data, rs.data, per, cum, lo, total)
    return Relation(out, total), total


def cross(l: Relation, r: Relation):
    """Cartesian product (rare in practice; needed for disconnected bodies)."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity), 0
    total = l.count * r.count
    out_cap = next_pow2(total)
    li = jnp.repeat(jnp.arange(l.count), r.count, total_repeat_length=total)
    ri = jnp.tile(jnp.arange(r.count), l.count)[:total]
    out = jnp.full((out_cap, l.arity + r.arity), PAD, jnp.int32)
    rows = jnp.concatenate([l.data[li], r.data[ri]], axis=1)
    out = jax.lax.dynamic_update_slice(out, rows, (0, 0))
    return Relation(out, total), total


# ---------------------------------------------------------------------------
# lexicographic binary search (shared by antijoin + merge_union)
# ---------------------------------------------------------------------------
def _range_narrow(col, key, lo, hi):
    """Per-row binary search narrowing [lo,hi) to col==key (col sorted within
    each [lo,hi) range by lexsort invariant).  The step loop is a
    ``fori_loop`` so the traced graph stays small — these searches are built
    per capacity bucket and an unrolled log2(n) body made recompilation the
    dominant cost as the store grows through buckets."""
    n = col.shape[0]
    steps = max(1, int(np.ceil(np.log2(n + 1))))

    def bs(le):
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            v = col[jnp.clip(mid, 0, n - 1)]
            go_right = jnp.where(le, v <= key, v < key)
            in_range = mid < h
            l = jnp.where(jnp.logical_and(in_range, go_right), mid + 1, l)
            h = jnp.where(jnp.logical_and(in_range,
                                          jnp.logical_not(go_right)), mid, h)
            return l, h
        return jax.lax.fori_loop(0, steps, body, (lo, hi))[0]

    return bs(False), bs(True)


def _lex_searchsorted_left(hay, probe):
    """Leftmost insertion positions of each ``probe`` row in lexsorted
    ``hay``: per-column range narrowing; when a column value is absent the
    range collapses to the insertion point and stays there."""
    lo = jnp.zeros(probe.shape[0], jnp.int32)
    hi = jnp.full(probe.shape[0], hay.shape[0], jnp.int32)
    for c in range(hay.shape[1]):
        lo, hi = _range_narrow(hay[:, c], probe[:, c], lo, hi)
    return lo


# ---------------------------------------------------------------------------
# antijoin (Def. 23 / redundancy filtering): drop rows whose key-tuple occurs
# in a sorted haystack relation
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _anti_count_fn(cap, ar, hcap, har, cols):
    @jax.jit
    def f(data, hay_sorted):
        probe = data[:, jnp.array(cols, jnp.int32)]
        lo = jnp.zeros(probe.shape[0], jnp.int32)
        hi = jnp.full(probe.shape[0], hay_sorted.shape[0], jnp.int32)
        for c in range(har):
            lo, hi = _range_narrow(hay_sorted[:, c], probe[:, c], lo, hi)
        found = hi > lo
        valid = data[:, 0] != PAD
        keep = jnp.logical_and(valid, jnp.logical_not(found))
        return jnp.sum(keep), keep
    return f


@lru_cache(maxsize=None)
def _anti_count_pallas_fn(cap, ar, hcap, col):
    """Single-key-column probe through the Pallas binary-search kernel."""
    K = _kernels()

    @jax.jit
    def f(data, hay_sorted):
        found = K.probe_sorted(data[:, col], hay_sorted[:, 0])
        valid = data[:, 0] != PAD
        keep = jnp.logical_and(valid, found == 0)
        return jnp.sum(keep), keep
    return f


def antijoin(rel: Relation, hay: Relation, cols=None) -> Relation:
    """Rows of rel whose ``cols``-tuple is NOT in hay.  The haystack lexsort
    is skipped when ``hay`` carries the full-lexsort marker (the store
    invariant); the output keeps ``rel``'s marker since compaction preserves
    row order."""
    if rel.count == 0:
        return rel
    if hay.count == 0:
        return rel
    cols = tuple(cols) if cols is not None else tuple(range(rel.arity))
    assert len(cols) == hay.arity
    hs = lexsort_rows(hay)
    if (use_pallas() and hay.arity == 1 and _is_pow2(rel.capacity)
            and _is_pow2(hs.capacity)):
        n, keep = _anti_count_pallas_fn(rel.capacity, rel.arity, hs.capacity,
                                        cols[0])(rel.data, hs.data)
    else:
        n, keep = _anti_count_fn(rel.capacity, rel.arity, hs.capacity,
                                 hay.arity, cols)(rel.data, hs.data)
    n = int(n)
    if n == rel.count:
        return rel
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, keep)
    return Relation(out, n, rel.sorted_by)


# ---------------------------------------------------------------------------
# union / append / merge
# ---------------------------------------------------------------------------
def union(a: Relation, b: Relation, dedupe: bool = True) -> Relation:
    """Concat-union.  With ``dedupe`` the result is lexsorted (dedup sorts);
    without, the concatenation clears any sortedness marker."""
    if a.count == 0:
        return b
    if b.count == 0:
        return a
    n = a.count + b.count
    cap = next_pow2(n)
    data = jnp.full((cap, a.arity), PAD, jnp.int32)
    data = jax.lax.dynamic_update_slice(data, a.data[:a.count], (0, 0))
    data = jax.lax.dynamic_update_slice(data, b.data[:b.count], (a.count, 0))
    out = Relation(data, n)
    return dedup(out) if dedupe else out


def _fit_rows(data, out_cap):
    """Slice or PAD-extend to ``out_cap`` rows (rows >= count are PAD either
    way) so the merge jit cache keys on the output bucket, not the store's."""
    cap = data.shape[0]
    if cap == out_cap:
        return data
    if cap > out_cap:
        return data[:out_cap]
    return jnp.concatenate(
        [data, jnp.full((out_cap - cap, data.shape[1]), PAD, jnp.int32)])


@lru_cache(maxsize=None)
def _merge_fn(cap, bcap, ar):
    """Merge small sorted delta B (bcap rows) into sorted store A (padded to
    the output bucket ``cap``).  Only the delta side is binary-searched —
    bcap probes, not cap — and the store side's shifts are recovered from a
    histogram of the delta insertion points + cumsum (O(cap) streaming work):
    output slot of B[i] = i + p_i where p_i = #{A lex< B[i]}, and output slot
    of A[j] = j + #{i : p_i <= j}."""
    out_cap = cap

    @jax.jit
    def f(A, B, na, nb):
        ia = jnp.arange(cap, dtype=jnp.int32)
        ib = jnp.arange(bcap, dtype=jnp.int32)
        valid_b = ib < nb
        # insertion position of each delta row in the store; PAD rows are
        # lex-max so p only counts valid store rows
        p = _lex_searchsorted_left(A, B)
        h = jnp.zeros(cap + 1, jnp.int32)
        h = h.at[jnp.where(valid_b, p, cap)].add(1, mode="drop")
        cnt = jnp.cumsum(h)[:cap]            # #{valid delta rows lex< A[j]}
        pos_a = jnp.where(ia < na, ia + cnt, out_cap)
        pos_b = jnp.where(valid_b, ib + p, out_cap)
        out = jnp.full((out_cap, ar), PAD, jnp.int32)
        out = out.at[pos_a].set(A, mode="drop")
        out = out.at[pos_b].set(B, mode="drop")
        return out
    return f


def merge_union(a: Relation, b: Relation) -> Relation:
    """Incremental sorted union of two DISJOINT row sets: two lexicographic
    binary-search passes place every row, instead of concat + full resort.
    Inputs are lexsorted first (free when they carry the marker); the output
    is lexsorted and marked.  Disjointness (e.g. delta antijoined against the
    store) is required — equal rows across inputs would collide on one slot."""
    assert a.arity == b.arity
    if b.count == 0:
        return lexsort_rows(a)
    if a.count == 0:
        return lexsort_rows(b)
    if b.count > a.count:   # search the smaller side into the larger
        a, b = b, a
    a = lexsort_rows(a)
    b = lexsort_rows(b)
    n = a.count + b.count
    out_cap = next_pow2(n)
    out = _merge_fn(out_cap, b.capacity, a.arity)(
        _fit_rows(a.data, out_cap), b.data, a.count, b.count)
    SORT_STATS.merges += 1
    return Relation(out, n, lex_order(a.arity))
