"""Vectorized relational operators on padded narrow-dtype relations.

Rows carry the store dtype (``REPRO_STORE_DTYPE``: int16/int32/int64 —
see ``repro.engine.relation``); every core reads its PAD sentinel and key
widths off the input arrays, so one set of traced functions serves all
store widths (jit retraces per dtype via its aval cache).

Execution contracts
-------------------
Every primitive exists in two layers:

* **Traceable cores** (``*_core`` functions): pure, shape-stable jnp
  functions with no host interaction — callable inside any jitted program
  (the fused round executor in ``repro.engine.fused``, the ``shard_map``
  bodies in ``repro.engine.distributed``, or the two-phase wrappers below).
  Cores never choose capacities; output capacities are arguments.
* **Two-phase host wrappers** (``dedup``/``filter_rows``/``sm_join``/
  ``antijoin``/...): the host-facing API over ``Relation`` values.  Data-
  dependent sizes follow the two-phase pattern: a jitted *count* pass, a
  blocking device->host pull of the count (recorded in ``HOST_SYNC_STATS``),
  a host pow-2 bucket choice, then a jitted *materialize* pass.

``REPRO_FUSED=1`` makes ``materialize()`` route whole rounds (and, for
linear-tail fixpoints, the whole fixpoint via ``lax.while_loop``) through one
compiled XLA program built from the cores — see ``repro.engine.fused`` for
the capacity-planner / overflow-doubling contract.  The wrappers here remain
the reference path (``REPRO_FUSED=0``) and the fallback for programs the
fused planner does not cover (existential rules).

Sortedness invariant
--------------------
Operators honor the ``Relation.sorted_by`` marker: ``dedup``/``antijoin``/
``sm_join`` skip their sort pass when an input already carries the needed
order, and ``merge_union`` folds a small sorted delta into a sorted store
with two lexicographic binary-search passes instead of a concat-and-resort
(O((m+n)·ar·log) vs O((m+n)·log(m+n)) full sort work per call — and, more
importantly, no re-sorting of the already-sorted store).  ``SORT_STATS``
counts performed vs skipped sort passes; ``REPRO_SORTED_STORE=0`` disables
the fast paths for A/B benchmarking.

Kernel dispatch
---------------
Setting ``REPRO_USE_PALLAS=1`` routes the sort / unique-mask / membership-
probe inner loops through the Pallas kernels in ``repro.kernels.ops``
(``sort_with_payload``, ``unique_mask``, ``probe_sorted``; interpret mode on
CPU, compiled on TPU).  The jnp implementations here are the reference path
and the default.  Multi-column lexsorts and the merge-union binary searches
stay on the jnp path in both modes (the kernels are single-key).

Env-flag matrix
---------------
=================== ======= ====================================================
``REPRO_USE_PALLAS`` ``0``   Pallas kernels for sort/unique/probe inner loops
``REPRO_SORTED_STORE`` ``1`` sortedness markers + incremental merge-union
``REPRO_FUSED``      ``0``   fused round executor (one XLA program per round)
``REPRO_DIST``       ``0``   sharded shard_map executor over all local devices
``REPRO_DIST_FIXPOINT`` ``1`` linear-tail while_loop fixpoint inside shard_map
=================== ======= ====================================================
"""
from __future__ import annotations

import os
from dataclasses import dataclass, replace
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.relation import (PAD, Relation, lex_order, next_pow2,
                                   pad_of)


# ---------------------------------------------------------------------------
# dispatch switches + sort-pass / host-sync accounting
# ---------------------------------------------------------------------------
def use_pallas() -> bool:
    """Route sort/unique/probe inner loops through the Pallas kernels."""
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def sorted_store_enabled() -> bool:
    """Honor ``sorted_by`` markers (skip redundant sorts, merge unions)."""
    return os.environ.get("REPRO_SORTED_STORE", "1") != "0"


def fused_enabled() -> bool:
    """Route eligible materialization rounds through the fused executor."""
    return os.environ.get("REPRO_FUSED", "0") == "1"


def dist_enabled() -> bool:
    """Route eligible materialization through the sharded (shard_map)
    executor over every local device (``materialize(backend="dist")``)."""
    return os.environ.get("REPRO_DIST", "0") == "1"


def dist_fixpoint_enabled() -> bool:
    """Run linear-tail fixpoint phases of the distributed executor inside
    one ``lax.while_loop``-under-``shard_map`` program (on by default;
    ``REPRO_DIST_FIXPOINT=0`` forces the host-stepped per-round path for
    A/B comparison)."""
    return os.environ.get("REPRO_DIST_FIXPOINT", "1") != "0"


_KERNELS = None


def _kernels():
    global _KERNELS
    if _KERNELS is None:
        from repro.kernels import ops as _ko
        _KERNELS = _ko
    return _KERNELS


@dataclass
class SortStats:
    """Counts of sort passes performed / avoided (the paper's redundant-work
    argument, applied to the engine's own hot path)."""
    lexsort: int = 0       # full row lexsorts executed
    key_sort: int = 0      # single-key sorts executed (sm_join inputs)
    merges: int = 0        # incremental merge-unions executed
    skipped: int = 0       # sort passes avoided via a sorted_by marker

    def reset(self):
        self.lexsort = self.key_sort = self.merges = self.skipped = 0

    def total_sorts(self) -> int:
        return self.lexsort + self.key_sort


SORT_STATS = SortStats()


@dataclass
class HostSyncStats:
    """Blocking device->host synchronization points.

    Each two-phase wrapper pulls its count-pass result to the host before it
    can pick an output bucket (``count_pulls`` — one per primitive call).
    The fused executor pulls once per compiled round / fixpoint attempt
    (``fused_pulls``), the distributed executor once per sharded round
    attempt regardless of the shard count (``dist_pulls``, the TOTAL pull
    count including fixpoint-program exits); both count capacity-overflow
    recompile-and-retry events (``fused_retries`` / ``dist_retries`` —
    host-stepped round retries only; fixpoint-phase capacity retries are
    visible as extra ``dist_fixpoint_pulls`` instead, so retried rounds
    and fixpoint-phase exits stay distinguishable).

    The distributed while_loop fixpoint adds two counters:
    ``dist_fixpoint_pulls`` — pulls taken at fixpoint-program exits
    (convergence, tail-full fold-and-re-enter, or capacity retry; each is
    also counted in ``dist_pulls``) — and ``dist_fixpoint_iters`` — rounds
    executed on-device inside the loop with NO host pull.  The accounting
    invariant the tests assert:

        dist_pulls == (rounds - dist_fixpoint_iters)   # host-stepped rounds
                      + dist_retries                    # round retries
                      + dist_fixpoint_pulls             # fixpoint exits

    ``total()`` is the engine's host-sync work metric, reported next to
    trigger counts by the benchmarks."""
    count_pulls: int = 0
    fused_pulls: int = 0
    fused_retries: int = 0
    dist_pulls: int = 0
    dist_retries: int = 0
    dist_fixpoint_pulls: int = 0
    dist_fixpoint_iters: int = 0

    def reset(self):
        self.count_pulls = self.fused_pulls = self.fused_retries = 0
        self.dist_pulls = self.dist_retries = 0
        self.dist_fixpoint_pulls = self.dist_fixpoint_iters = 0

    def snapshot(self) -> "HostSyncStats":
        """Immutable copy of the current counters — callers comparing
        before/after an operation (e.g. the mid-run-restore invariant
        tests) hold a snapshot instead of racing the live singleton."""
        return replace(self)

    def total(self) -> int:
        return self.count_pulls + self.fused_pulls + self.dist_pulls


HOST_SYNC_STATS = HostSyncStats()


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


# ===========================================================================
# traceable cores — pure jnp, shape-stable, no host interaction.  Safe to
# call inside jit / while_loop / shard_map; static args (column indices,
# capacities, pallas routing) must be python values at trace time.
# ===========================================================================
def lexsort_core(data, pallas: bool | None = None):
    """Full-row lexicographic sort of a padded (cap, ar) block (PAD rows
    sort last).  Single-column blocks route through the Pallas sort kernel
    when ``pallas`` (pow-2 caps only)."""
    cap, ar = data.shape
    if pallas is None:
        pallas = use_pallas()
    if pallas and ar == 1 and _is_pow2(cap):
        return keysort_core(data, 0, pallas=True)
    if ar == 2 and _pack_ok(data.dtype):
        with jax.experimental.enable_x64():
            order = jnp.argsort(pack_rows2(data)).astype(jnp.int32)
        return data[order]
    keys = tuple(data[:, c] for c in reversed(range(ar)))
    return data[jnp.lexsort(keys)]


def keysort_core(data, key_col: int, pallas: bool | None = None):
    """Sort rows of a padded block by one key column."""
    cap = data.shape[0]
    if pallas is None:
        pallas = use_pallas()
    if pallas and _is_pow2(cap):
        K = _kernels()
        vals = jnp.arange(cap, dtype=jnp.int32)
        _, perm = K.sort_with_payload(data[:, key_col], vals,
                                      tile=min(1024, cap))
        return data[perm]
    return data[jnp.argsort(data[:, key_col])]


def dedup_mask_core(sorted_data, pallas: bool | None = None):
    """First-occurrence mask over lexsorted rows (PAD rows excluded)."""
    if pallas is None:
        pallas = use_pallas()
    if pallas:
        K = _kernels()
        return K.unique_mask(sorted_data).astype(bool)
    prev = jnp.roll(sorted_data, 1, axis=0)
    neq = jnp.any(sorted_data != prev, axis=1)
    neq = neq.at[0].set(True)
    valid = sorted_data[:, 0] != pad_of(sorted_data)
    return jnp.logical_and(neq, valid)


def filter_mask_core(data, eq_pairs=(), const_pairs=()):
    """Row-selection mask: valid rows meeting column-equality (repeated
    vars) and column-constant constraints."""
    valid = data[:, 0] != pad_of(data)
    for a, b in eq_pairs:
        valid &= data[:, a] == data[:, b]
    for c, v in const_pairs:
        valid &= data[:, c] == v
    return valid


def compact_core(data, mask, out_cap: int):
    """Scatter masked rows to the front of a fresh (out_cap, ar) PAD block,
    preserving their relative order (so sortedness survives compaction).
    Rows beyond ``out_cap`` are dropped — callers detect that via
    ``sum(mask) > out_cap``."""
    pos = jnp.cumsum(mask) - 1
    idx = jnp.where(mask, pos, out_cap)
    out = jnp.full((out_cap + 1, data.shape[1]), pad_of(data), data.dtype)
    out = out.at[idx].set(data, mode="drop")
    return out[:out_cap]


def project_core(data, cols):
    """Column gather; invalid (PAD) rows stay fully PAD."""
    valid = data[:, 0] != pad_of(data)
    out = data[:, jnp.array(cols, jnp.int32)]
    return jnp.where(valid[:, None], out, pad_of(data))


def join_count_core(ldata, rdata_sorted, lkey: int, rkey: int):
    """Count pass of the sort-merge join: per-left-row match ranges in the
    right block (sorted by ``rkey``).  Returns (total, per, cum, lo)."""
    lk = ldata[:, lkey]
    rk = rdata_sorted[:, rkey]
    lo = jnp.searchsorted(rk, lk, side="left")
    hi = jnp.searchsorted(rk, lk, side="right")
    per = jnp.where(lk != pad_of(ldata), hi - lo, 0)
    cum = jnp.cumsum(per) - per           # exclusive prefix
    return jnp.sum(per), per, cum, lo


def join_gather_core(ldata, rdata, per, cum, lo, total, out_cap: int):
    """Materialize pass: emit [l cols..., r cols...] rows into a
    (out_cap, lar+rar) block.  Rows past ``out_cap`` are dropped (overflow
    is ``total > out_cap``, checked by the caller)."""
    lcap = ldata.shape[0]
    rcap = rdata.shape[0]
    t = jnp.arange(out_cap)
    # left row for output t: last i with cum[i] <= t
    i = jnp.searchsorted(cum + per, t, side="right")
    i = jnp.clip(i, 0, lcap - 1)
    j = jnp.clip(lo[i] + (t - cum[i]), 0, rcap - 1)
    valid = t < total
    out = jnp.concatenate([ldata[i], rdata[j]], axis=1)
    return jnp.where(valid[:, None], out, pad_of(ldata))


def _range_narrow(col, key, lo, hi):
    """Per-row binary search narrowing [lo,hi) to col==key (col sorted within
    each [lo,hi) range by lexsort invariant).  The step loop is a
    ``fori_loop`` so the traced graph stays small — these searches are built
    per capacity bucket and an unrolled log2(n) body made recompilation the
    dominant cost as the store grows through buckets."""
    n = col.shape[0]
    steps = max(1, int(np.ceil(np.log2(n + 1))))

    def bs(le):
        def body(_, lh):
            l, h = lh
            mid = (l + h) // 2
            v = col[jnp.clip(mid, 0, n - 1)]
            go_right = jnp.where(le, v <= key, v < key)
            in_range = mid < h
            l = jnp.where(jnp.logical_and(in_range, go_right), mid + 1, l)
            h = jnp.where(jnp.logical_and(in_range,
                                          jnp.logical_not(go_right)), mid, h)
            return l, h
        return jax.lax.fori_loop(0, steps, body, (lo, hi))[0]

    return bs(False), bs(True)


# pack target per store dtype: two narrow columns bitcast into one key of
# double the width.  int64 rows have no 128-bit key — they take the
# per-column binary-search path instead (the honest wide-baseline cost).
_PACK_KEY = {
    np.dtype(np.int16): jnp.int32,
    np.dtype(np.int32): jnp.int64,
}


def pack_rows2(rows):
    """Pack (cap, 2) non-negative narrow rows into one double-width key per
    row that preserves lexicographic order (dictionary ids are non-negative
    and PAD = dtype max, so packed PAD rows stay lex-maximal).  Turns the
    per-column binary-search loops into single XLA-native sort/searchsorted
    calls for the dominant arity-2 case.

    Implemented as a bitcast (low word first — little-endian on CPU/GPU)
    rather than shift-add: with the global x64 flag off, int64 *constants*
    are canonicalized to int32 during lowering, but a constant-free bitcast
    survives; ``enable_x64`` covers the trace-time aval creation (a no-op
    for the int16 -> int32 pack, which never leaves 32-bit)."""
    out_dt = _PACK_KEY[np.dtype(rows.dtype)]
    with jax.experimental.enable_x64():
        pair = jnp.stack([rows[:, 1], rows[:, 0]], axis=1)
        return jax.lax.bitcast_convert_type(pair, out_dt)


def lex_range_core(hay_sorted, probe):
    """Per-probe-row [lo, hi) occurrence range in a lexsorted haystack:
    per-column range narrowing; when a column value is absent the range
    collapses to the insertion point and stays there."""
    lo = jnp.zeros(probe.shape[0], jnp.int32)
    hi = jnp.full(probe.shape[0], hay_sorted.shape[0], jnp.int32)
    for c in range(hay_sorted.shape[1]):
        lo, hi = _range_narrow(hay_sorted[:, c], probe[:, c], lo, hi)
    return lo, hi


def _pack_ok(dtype=np.int32) -> bool:
    """Whether arity-2 rows of ``dtype`` can pack into one scalar key:
    int16 pairs pack to int32 (native everywhere); int32 pairs pack to
    int64 (needs a backend with native 64-bit support); int64 pairs have
    no 128-bit key dtype and fall back to per-column binary search."""
    dt = np.dtype(dtype)
    if dt == np.int16:
        return True
    if dt == np.int32:
        return jax.default_backend() != "tpu"
    return False


def _lex_keys(hay, probe):
    """Order-preserving scalar keys for rows of arity <= 2, else None."""
    if hay.shape[1] == 1:
        return hay[:, 0], probe[:, 0]
    if hay.shape[1] == 2 and _pack_ok(hay.dtype):
        return pack_rows2(hay), pack_rows2(probe)
    return None


def _lex_searchsorted_left(hay, probe):
    """Leftmost insertion positions of each ``probe`` row in lexsorted
    ``hay``."""
    keys = _lex_keys(hay, probe)
    if keys is not None:
        with jax.experimental.enable_x64():
            return jnp.searchsorted(keys[0], keys[1], side="left"
                                    ).astype(jnp.int32)
    return lex_range_core(hay, probe)[0]


def _lex_searchsorted_right(hay, probe):
    """Rightmost insertion positions of each ``probe`` row in lexsorted
    ``hay``."""
    keys = _lex_keys(hay, probe)
    if keys is not None:
        with jax.experimental.enable_x64():
            return jnp.searchsorted(keys[0], keys[1], side="right"
                                    ).astype(jnp.int32)
    return lex_range_core(hay, probe)[1]


def member_mask_core(probe_rows, hay_sorted):
    """Row membership of each probe row in a lexsorted haystack (PAD probe
    rows report non-member: PAD columns never match valid haystack rows and
    match only haystack PAD padding, which is excluded either way)."""
    valid = probe_rows[:, 0] != pad_of(probe_rows)
    keys = _lex_keys(hay_sorted, probe_rows)
    if keys is not None:
        hk, pk = keys
        n = hk.shape[0]
        # int64 stays confined to the key arrays: index math runs in int32
        # so no int64 constants reach lowering (which would canonicalize
        # them to int32 under the global x64-off flag)
        with jax.experimental.enable_x64():
            idx = jnp.searchsorted(hk, pk).astype(jnp.int32)
            # no jnp.clip here: it is an internally-jitted helper whose
            # cached trace clashes across x64 contexts
            idx_c = jnp.minimum(jnp.maximum(idx, 0), n - 1)
            found = hk[idx_c] == pk
        found = jnp.logical_and(found, idx < n)
        return jnp.logical_and(found, valid)
    lo, hi = lex_range_core(hay_sorted, probe_rows)
    return jnp.logical_and(hi > lo, valid)


def anti_keep_core(data, hay_sorted, cols, pallas: bool | None = None):
    """Keep-mask for the antijoin: valid rows of ``data`` whose ``cols``
    tuple does NOT occur in the lexsorted haystack.  Single-column probes
    route through the Pallas binary-search kernel when ``pallas``."""
    if pallas is None:
        pallas = use_pallas()
    valid = data[:, 0] != pad_of(data)
    if (pallas and hay_sorted.shape[1] == 1 and len(cols) == 1
            and _is_pow2(data.shape[0]) and _is_pow2(hay_sorted.shape[0])):
        K = _kernels()
        found = K.probe_sorted(data[:, cols[0]], hay_sorted[:, 0]) != 0
    else:
        found = member_mask_core(project_core(data, cols), hay_sorted)
    return jnp.logical_and(valid, jnp.logical_not(found))


def merge_diff_core(A, B_sorted, out_cap: int, pallas: bool | None = None):
    """Sorted set-difference: rows of block A (lexsorted) minus rows of
    lexsorted block B, compacted into a fresh (out_cap, ar) PAD block.
    Mirrors ``merge_core``'s binary-search discipline — every A row is one
    lexicographic membership probe into B, no sort pass — and preserves A's
    order (compaction keeps relative order).  Returns (out, n_kept); overflow
    is ``n_kept > out_cap``, checked by the caller."""
    keep = anti_keep_core(A, B_sorted, tuple(range(A.shape[1])),
                          pallas=pallas)
    n = jnp.sum(keep).astype(jnp.int32)
    return compact_core(A, keep, out_cap), n


def merge_core(A, B, na, nb):
    """Merge sorted block B (bcap rows, nb valid) into sorted block A
    (out_cap rows, na valid).  Duplicate rows may appear within and across
    the blocks: ties place the A run first (a stable multiset merge), so
    disjoint-set callers (the sorted-store fold) and multiset callers (the
    exchange run merge) share one core.  Only the B side is binary-searched
    — bcap probes, not out_cap — and the A side's shifts are recovered from
    a histogram of the B insertion points + cumsum (O(out_cap) streaming
    work): output slot of B[i] = i + p_i where p_i = #{A lex<= B[i]}, and
    output slot of A[j] = j + #{i : p_i <= j}.  The output capacity is A's;
    overflow is ``na + nb > A.shape[0]``, checked by the caller."""
    out_cap, ar = A.shape
    bcap = B.shape[0]
    ia = jnp.arange(out_cap, dtype=jnp.int32)
    ib = jnp.arange(bcap, dtype=jnp.int32)
    valid_b = ib < nb
    # insertion position of each B row AFTER any equal A rows; PAD rows are
    # lex-max so p only counts valid A rows
    p = _lex_searchsorted_right(A, B)
    h = jnp.zeros(out_cap + 1, jnp.int32)
    h = h.at[jnp.where(valid_b, p, out_cap)].add(1, mode="drop")
    cnt = jnp.cumsum(h)[:out_cap]            # #{valid B rows lex< A[j]}
    pos_a = jnp.where(ia < na, ia + cnt, out_cap)
    pos_b = jnp.where(valid_b, ib + p, out_cap)
    out = jnp.full((out_cap, ar), pad_of(A), A.dtype)
    out = out.at[pos_a].set(A, mode="drop")
    out = out.at[pos_b].set(B, mode="drop")
    return out


# ===========================================================================
# two-phase host wrappers over the cores
# ===========================================================================
# ---------------------------------------------------------------------------
# sorting / dedup
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _lexsort_fn(cap, ar, pallas):
    @jax.jit
    def f(data):
        return lexsort_core(data, pallas=pallas)
    return f


def lexsort_rows(rel: Relation) -> Relation:
    order = lex_order(rel.arity)
    if sorted_store_enabled() and rel.sorted_by == order:
        SORT_STATS.skipped += 1
        return rel
    data = _lexsort_fn(rel.capacity, rel.arity, use_pallas())(rel.data)
    SORT_STATS.lexsort += 1
    return Relation(data, rel.count, order)


@lru_cache(maxsize=None)
def _dedup_count_fn(cap, ar, pallas):
    @jax.jit
    def f(sorted_data):
        mask = dedup_mask_core(sorted_data, pallas=pallas)
        return jnp.sum(mask), mask
    return f


@lru_cache(maxsize=None)
def _compact_fn(cap, ar, out_cap):
    @jax.jit
    def f(data, mask):
        return compact_core(data, mask, out_cap)
    return f


def dedup(rel: Relation) -> Relation:
    """Sort (skipped on a lexsorted input) + adjacent-unique + compact.
    Output is lexsorted and marked."""
    if rel.count == 0:
        return Relation.empty(rel.arity, dtype=rel.dtype)
    s = lexsort_rows(rel)
    n, mask = _dedup_count_fn(s.capacity, s.arity, use_pallas())(s.data)
    n = int(n)
    HOST_SYNC_STATS.count_pulls += 1
    cap = next_pow2(n)
    out = _compact_fn(s.capacity, s.arity, cap)(s.data, mask)
    return Relation(out, n, lex_order(rel.arity))


# ---------------------------------------------------------------------------
# filters / projection
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _filter_count_fn(cap, ar, eq_pairs, const_pairs):
    @jax.jit
    def f(data):
        valid = filter_mask_core(data, eq_pairs, const_pairs)
        return jnp.sum(valid), valid
    return f


def filter_rows(rel: Relation, eq_pairs=(), const_pairs=()) -> Relation:
    """Select rows with col equality (repeated vars) / constant constraints.
    Compaction keeps row order, so the sortedness marker is preserved."""
    if rel.count == 0 or (not eq_pairs and not const_pairs):
        return rel
    n, mask = _filter_count_fn(rel.capacity, rel.arity, tuple(eq_pairs),
                               tuple(const_pairs))(rel.data)
    n = int(n)
    HOST_SYNC_STATS.count_pulls += 1
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, mask)
    return Relation(out, n, rel.sorted_by)


@lru_cache(maxsize=None)
def _project_fn(cap, ar, cols):
    @jax.jit
    def f(data):
        return project_core(data, cols)
    return f


def project(rel: Relation, cols) -> Relation:
    if not cols:
        cols = (0,)
    return Relation(_project_fn(rel.capacity, rel.arity, tuple(cols))(rel.data),
                    rel.count)


# ---------------------------------------------------------------------------
# sort-merge join (single int32 key column; multi-column keys are packed by
# the planner with post-join verification)
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _sortby_fn(cap, ar, key_col, pallas):
    @jax.jit
    def f(data):
        return keysort_core(data, key_col, pallas=pallas)
    return f


def sort_by(rel: Relation, key_col: int) -> Relation:
    """Sort by one key column; skipped when ``sorted_by`` already starts with
    that column (a lexsorted relation is sorted by its primary column)."""
    if (sorted_store_enabled() and rel.sorted_by
            and rel.sorted_by[0] == key_col):
        SORT_STATS.skipped += 1
        return rel
    data = _sortby_fn(rel.capacity, rel.arity, key_col,
                      use_pallas())(rel.data)
    SORT_STATS.key_sort += 1
    return Relation(data, rel.count, (key_col,))


@lru_cache(maxsize=None)
def _join_count_fn(lcap, lar, rcap, rar, lkey, rkey):
    @jax.jit
    def f(l, r):
        return join_count_core(l, r, lkey, rkey)
    return f


@lru_cache(maxsize=None)
def _join_mat_fn(lcap, lar, rcap, rar, out_cap):
    @jax.jit
    def f(l, r, per, cum, lo, total):
        return join_gather_core(l, r, per, cum, lo, total, out_cap)
    return f


def sm_join(l: Relation, r: Relation, lkey: int, rkey: int):
    """Sort-merge join; returns (Relation out, matches) where out columns are
    [l cols..., r cols...] and ``matches`` is the trigger count.  Input sorts
    are skipped for relations already sorted by their join key."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity, dtype=l.dtype), 0
    ls = sort_by(l, lkey)
    rs = sort_by(r, rkey)
    total, per, cum, lo = _join_count_fn(
        l.capacity, l.arity, r.capacity, r.arity, lkey, rkey)(ls.data, rs.data)
    total = int(total)
    HOST_SYNC_STATS.count_pulls += 1
    if total == 0:
        return Relation.empty(l.arity + r.arity), 0
    out_cap = next_pow2(total)
    out = _join_mat_fn(l.capacity, l.arity, r.capacity, r.arity, out_cap)(
        ls.data, rs.data, per, cum, lo, total)
    return Relation(out, total), total


def cross(l: Relation, r: Relation):
    """Cartesian product (rare in practice; needed for disconnected bodies)."""
    if l.count == 0 or r.count == 0:
        return Relation.empty(l.arity + r.arity, dtype=l.dtype), 0
    total = l.count * r.count
    out_cap = next_pow2(total)
    li = jnp.repeat(jnp.arange(l.count), r.count, total_repeat_length=total)
    ri = jnp.tile(jnp.arange(r.count), l.count)[:total]
    out = jnp.full((out_cap, l.arity + r.arity), pad_of(l.data), l.data.dtype)
    rows = jnp.concatenate([l.data[li], r.data[ri]], axis=1)
    out = jax.lax.dynamic_update_slice(out, rows, (0, 0))
    return Relation(out, total), total


# ---------------------------------------------------------------------------
# antijoin (Def. 23 / redundancy filtering): drop rows whose key-tuple occurs
# in a sorted haystack relation
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _anti_count_fn(cap, ar, hcap, har, cols, pallas):
    @jax.jit
    def f(data, hay_sorted):
        keep = anti_keep_core(data, hay_sorted, cols, pallas=pallas)
        return jnp.sum(keep), keep
    return f


def antijoin(rel: Relation, hay: Relation, cols=None) -> Relation:
    """Rows of rel whose ``cols``-tuple is NOT in hay.  The haystack lexsort
    is skipped when ``hay`` carries the full-lexsort marker (the store
    invariant); the output keeps ``rel``'s marker since compaction preserves
    row order."""
    if rel.count == 0:
        return rel
    if hay.count == 0:
        return rel
    cols = tuple(cols) if cols is not None else tuple(range(rel.arity))
    assert len(cols) == hay.arity
    hs = lexsort_rows(hay)
    n, keep = _anti_count_fn(rel.capacity, rel.arity, hs.capacity,
                             hay.arity, cols, use_pallas())(rel.data, hs.data)
    n = int(n)
    HOST_SYNC_STATS.count_pulls += 1
    if n == rel.count:
        return rel
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, keep)
    return Relation(out, n, rel.sorted_by)


# ---------------------------------------------------------------------------
# semijoin (DRed restriction): keep rows whose key-tuple occurs in a sorted
# haystack relation — the inverted Def. 23 pre-restriction used by deletion
# propagation (only facts already in the store can be over-deleted)
# ---------------------------------------------------------------------------
@lru_cache(maxsize=None)
def _semi_count_fn(cap, ar, hcap, har, cols):
    @jax.jit
    def f(data, hay_sorted):
        valid = data[:, 0] != pad_of(data)
        found = member_mask_core(project_core(data, cols), hay_sorted)
        keep = jnp.logical_and(valid, found)
        return jnp.sum(keep), keep
    return f


def semijoin(rel: Relation, hay: Relation, cols=None) -> Relation:
    """Rows of rel whose ``cols``-tuple IS in hay (the antijoin's
    complement).  Same sortedness contract: the haystack lexsort is skipped
    when marked, and the output keeps ``rel``'s marker."""
    if rel.count == 0 or hay.count == 0:
        return Relation.empty(rel.arity, dtype=rel.dtype)
    cols = tuple(cols) if cols is not None else tuple(range(rel.arity))
    assert len(cols) == hay.arity
    hs = lexsort_rows(hay)
    n, keep = _semi_count_fn(rel.capacity, rel.arity, hs.capacity,
                             hay.arity, cols)(rel.data, hs.data)
    n = int(n)
    HOST_SYNC_STATS.count_pulls += 1
    if n == rel.count:
        return rel
    cap = next_pow2(n)
    out = _compact_fn(rel.capacity, rel.arity, cap)(rel.data, keep)
    return Relation(out, n, rel.sorted_by)


# ---------------------------------------------------------------------------
# union / append / merge
# ---------------------------------------------------------------------------
def union(a: Relation, b: Relation, dedupe: bool = True) -> Relation:
    """Concat-union.  With ``dedupe`` the result is lexsorted (dedup sorts);
    without, the concatenation clears any sortedness marker."""
    if a.count == 0:
        return b
    if b.count == 0:
        return a
    n = a.count + b.count
    cap = next_pow2(n)
    data = jnp.full((cap, a.arity), pad_of(a.data), a.data.dtype)
    data = jax.lax.dynamic_update_slice(data, a.data[:a.count], (0, 0))
    data = jax.lax.dynamic_update_slice(data, b.data[:b.count], (a.count, 0))
    out = Relation(data, n)
    return dedup(out) if dedupe else out


def fit_rows(data, out_cap):
    """Slice or PAD-extend to ``out_cap`` rows (rows >= count are PAD either
    way) so jit caches key on the planned output bucket, not the input's."""
    cap = data.shape[0]
    if cap == out_cap:
        return data
    if cap > out_cap:
        return data[:out_cap]
    return jnp.concatenate(
        [data, jnp.full((out_cap - cap, data.shape[1]), pad_of(data),
                        data.dtype)])


@lru_cache(maxsize=None)
def _merge_fn(cap, bcap, ar):
    @jax.jit
    def f(A, B, na, nb):
        return merge_core(A, B, na, nb)
    return f


def merge_union(a: Relation, b: Relation) -> Relation:
    """Incremental sorted union of two DISJOINT row sets: two lexicographic
    binary-search passes place every row, instead of concat + full resort.
    Inputs are lexsorted first (free when they carry the marker); the output
    is lexsorted and marked.  Disjointness (e.g. delta antijoined against the
    store) is required — equal rows across inputs would collide on one slot."""
    assert a.arity == b.arity
    if b.count == 0:
        return lexsort_rows(a)
    if a.count == 0:
        return lexsort_rows(b)
    if b.count > a.count:   # search the smaller side into the larger
        a, b = b, a
    a = lexsort_rows(a)
    b = lexsort_rows(b)
    n = a.count + b.count
    out_cap = next_pow2(n)
    out = _merge_fn(out_cap, b.capacity, a.arity)(
        fit_rows(a.data, out_cap), b.data, a.count, b.count)
    SORT_STATS.merges += 1
    return Relation(out, n, lex_order(a.arity))


@lru_cache(maxsize=None)
def _diff_fn(cap, hcap, ar, out_cap, pallas):
    @jax.jit
    def f(A, B):
        return merge_diff_core(A, B, out_cap, pallas=pallas)
    return f


def merge_diff(a: Relation, b: Relation) -> Relation:
    """Incremental sorted set-difference ``a - b`` (full rows), the deletion
    counterpart of ``merge_union``: both sides are lexsorted first (free when
    they carry the marker), every ``a`` row is one binary-search membership
    probe into ``b``, and the surviving rows compact in place — no re-sort of
    the store.  Output is lexsorted and marked."""
    assert a.arity == b.arity
    if a.count == 0 or b.count == 0:
        return lexsort_rows(a)
    a = lexsort_rows(a)
    b = lexsort_rows(b)
    # keep a's buffer capacity: the difference always fits, and preserving
    # the shape keeps downstream jit signatures stable across delete calls
    # (a shrink-to-fit here would recompile every store consumer)
    out_cap = a.capacity
    out, n = _diff_fn(a.capacity, b.capacity, a.arity, out_cap,
                      use_pallas())(a.data, b.data)
    n = int(n)
    HOST_SYNC_STATS.count_pulls += 1
    SORT_STATS.merges += 1
    return Relation(out, n, lex_order(a.arity))
