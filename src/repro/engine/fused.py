"""Fused round executor: one XLA program per materialization round.

The two-phase wrappers in ``repro.engine.ops`` pull every data-dependent
count to the host (one blocking sync per primitive call) to pick pow-2
output buckets — on small-delta rounds those host round-trips, not the join
arithmetic, dominate wall time.  This module removes them:

* The **rule-plan IR** (``repro.engine.plan``: ``RulePlan`` /
  ``compile_rule_plan``), its capacity planner (``_Caps``), and the traced
  round pieces (``_exec_rule_traced`` / ``_absorb_traced``) are backend-
  neutral — the distributed executor consumes the same plans.  This module
  stitches them into one jitted, shape-stable program per (rule set,
  capacity plan): body filters, the Def. 23 antijoin pre-restriction, the
  sort-merge join chain, head projection, and the per-predicate absorb
  (dedup + antijoin vs store + incremental sorted merge) all run in a
  single XLA executable.  The only device->host traffic per round is one
  scalar bundle: counts, the trigger total, and an overflow vector
  (``HOST_SYNC_STATS.fused_pulls``).
* A **fused fixpoint driver** runs whole semi-naive/TG rounds this way, and
  once the remaining computation is *linear* — every still-active rule has
  exactly one body atom whose predicate can still change — it finishes the
  entire fixpoint inside one ``lax.while_loop``, with loop-state buffers
  donated to XLA on accelerator backends.

Overflow semantics (mirrors the distributed bucket-exchange contract):
every planned capacity gets an in-program overflow flag (``needed >
planned``).  When any flag fires the round's outputs are discarded, the
host doubles exactly the overflowed capacities, recompiles at the new
buckets, and retries the same round from the inputs it still holds
(``HOST_SYNC_STATS.fused_retries``).  Inside the fixpoint loop an overflow
exits with the *last good* state, so the retry resumes mid-fixpoint — it
never recomputes from scratch.

Eligibility: Datalog rules (no existentials) with connected bodies.
``materialize()`` falls back to the two-phase path for anything else.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import ops, recovery
from repro.engine.plan import (_absorb_traced, _cached_program, _Caps,
                               _exec_rule_traced, _linear_tail,
                               _select_state, CapacityError,
                               compile_rule_plan, program_fingerprint,
                               RetryBudget, RulePlan)
from repro.engine.relation import Relation, lex_order, pad_of

__all__ = ["RulePlan", "compile_rule_plan", "materialize_fused",
           "lower_fused_programs"]


# ---------------------------------------------------------------------------
# compiled round program
# ---------------------------------------------------------------------------
def _round_signature(preds, caps, active, delta_in, use_prefilter, pallas):
    return ("round", preds,
            tuple(caps.store[p] for p in preds),
            tuple((plan.key, jd, tuple(caps.join_cap(plan, i)
                                       for i in range(len(plan.joins))))
                  for plan, jd in active),
            tuple((p, caps.delta_cap(p)) for p in delta_in),
            tuple(sorted((p, caps.delta_cap(p)) for p in
                         {plan.head_pred for plan, _ in active})),
            use_prefilter, pallas)


def _build_round(preds, caps, active, delta_in, use_prefilter, pallas):
    """One materialization round as a single jitted program.

    Inputs: per-pred store blocks (at planner capacities) + counts, plus the
    live delta blocks (at planner delta capacities).  Outputs: new stores /
    counts, new per-derived-pred deltas + counts, the round's trigger total,
    and the overflow vector.  ``ovf_labels`` names each overflow slot so the
    driver can double exactly the right capacity."""
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    ovf_labels = []
    for plan, jd in active:
        for i in range(len(plan.joins)):
            ovf_labels.append(("join", (plan.key, i)))
    for pred in derived:
        ovf_labels.append(("delta", pred))
        ovf_labels.append(("store", pred))
    join_caps = {id(plan): tuple(caps.join_cap(plan, i)
                                 for i in range(len(plan.joins)))
                 for plan, _ in active}
    delta_caps = {p: caps.delta_cap(p) for p in derived}

    def fn(store_datas, store_counts, delta_datas):
        stores = dict(zip(preds, store_datas))
        counts = dict(zip(preds, store_counts))
        deltas = dict(zip(delta_in, delta_datas))
        triggers = jnp.zeros((), jnp.int32)
        ovfs = []
        heads = {}
        for plan, jd in active:
            inputs = [deltas[bp] if j == jd else stores[bp]
                      for j, bp in enumerate(plan.body_preds)]
            pre_data = stores[plan.head_pred] if use_prefilter else None
            head, trg, jovfs = _exec_rule_traced(plan, inputs, pre_data,
                                                 join_caps[id(plan)], pallas)
            triggers += trg
            ovfs += jovfs
            heads.setdefault(plan.head_pred, []).append(head)
        out_deltas, out_dcounts = [], []
        for pred in derived:
            ns, nc, delta, nf, (od, os_) = _absorb_traced(
                heads[pred],
                lambda rows, p=pred: jnp.logical_not(
                    ops.member_mask_core(rows, stores[p])),
                stores[pred], counts[pred], delta_caps[pred], pallas)
            stores[pred] = ns
            counts[pred] = nc
            out_deltas.append(delta)
            out_dcounts.append(nf)
            ovfs += [od, os_]
        ovf_vec = (jnp.stack(ovfs) if ovfs
                   else jnp.zeros((0,), jnp.bool_))
        return (tuple(stores[p] for p in preds),
                tuple(counts[p] for p in preds),
                tuple(out_deltas), tuple(out_dcounts), triggers, ovf_vec)

    return jax.jit(fn), ovf_labels, derived


# ---------------------------------------------------------------------------
# fused fixpoint (lax.while_loop over whole rounds; linear-tail detection
# and the last-good-state select are shared with the distributed fixpoint
# via repro.engine.plan)
# ---------------------------------------------------------------------------
def _fix_signature(s_preds, o_preds, caps, active, use_prefilter, pallas,
                   max_rounds, donate):
    return ("fix", s_preds, o_preds,
            tuple(caps.store[p] for p in s_preds + o_preds),
            tuple(caps.delta_cap(p) for p in s_preds),
            tuple(caps.tail_cap(p) for p in s_preds),
            tuple((plan.key, jd, tuple(caps.join_cap(plan, i)
                                       for i in range(len(plan.joins))))
                  for plan, jd in active),
            use_prefilter, pallas, max_rounds, donate)


def _build_fixpoint(s_preds, o_preds, caps, active, use_prefilter, pallas,
                    max_rounds, donate):
    """The remaining (linear) fixpoint as one ``lax.while_loop`` program.

    Loop state: the deltas of the still-changing predicates plus a small
    sorted *tail* buffer per predicate.  The phase-entry stores are loop
    CONSTANTS — redundancy filtering probes (base store | tail), and each
    round's fresh facts merge into the tail (O(tail) work per iteration,
    not O(store)).  When a tail fills, the loop exits with the last good
    state, the host folds the tail into its store once, and the loop
    re-enters — the fixpoint resumes, never restarts.  Join/delta capacity
    overflows exit the same way and retry after host-side doubling."""
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    ovf_labels = []
    for plan, jd in active:
        for i in range(len(plan.joins)):
            ovf_labels.append(("join", (plan.key, i)))
    for pred in derived:
        ovf_labels.append(("delta", pred))
        ovf_labels.append(("tail", pred))
    n_ovf = len(ovf_labels)
    join_caps = {id(plan): tuple(caps.join_cap(plan, i)
                                 for i in range(len(plan.joins)))
                 for plan, _ in active}
    delta_caps = {p: caps.delta_cap(p) for p in s_preds}

    def fn(s_base, w_datas, w_counts, d_datas, d_counts, o_datas, rounds):
        base = dict(zip(s_preds, s_base))
        others = dict(zip(o_preds, o_datas))

        def not_seen(rows, pred, tails, cols=None):
            """keep-mask: rows whose tuple is in neither the phase-entry
            store nor the tail of ``pred``."""
            sel = rows if cols is None else ops.project_core(rows, cols)
            seen = jnp.logical_or(
                ops.member_mask_core(sel, base[pred]),
                ops.member_mask_core(sel, tails[pred]))
            valid = rows[:, 0] != pad_of(rows)
            return jnp.logical_and(valid, jnp.logical_not(seen))

        def body(state):
            w_datas, w_counts, d_datas, d_counts, rounds, trg, drv, _ = state
            tails = dict(zip(s_preds, w_datas))
            wcnt = dict(zip(s_preds, w_counts))
            deltas = dict(zip(s_preds, d_datas))
            stores = dict(others)
            triggers = jnp.zeros((), jnp.int32)
            ovfs = []
            heads = {}
            for plan, jd in active:
                inputs = []
                for j, bp in enumerate(plan.body_preds):
                    # linear tail: the only S-pred body atom is the delta
                    inputs.append(deltas[bp] if j == jd else stores[bp])
                head, t, jovfs = _exec_rule_traced(
                    plan, inputs, None, join_caps[id(plan)], pallas,
                    prefilter=((lambda rows, cols, p=plan.head_pred:
                                not_seen(rows, p, tails, cols))
                               if use_prefilter else None))
                triggers += t
                ovfs += jovfs
                heads.setdefault(plan.head_pred, []).append(head)
            new_w, new_wc, new_deltas, new_dcounts = {}, {}, {}, {}
            for pred in s_preds:
                if pred in heads:
                    nw, nc, delta, nf, (od, ow) = _absorb_traced(
                        heads[pred],
                        lambda rows, p=pred: not_seen(rows, p, tails),
                        tails[pred], wcnt[pred], delta_caps[pred], pallas)
                    new_w[pred], new_wc[pred] = nw, nc
                    new_deltas[pred], new_dcounts[pred] = delta, nf
                    ovfs += [od, ow]
                else:   # in S but not derived by any active rule: drains
                    new_w[pred] = tails[pred]
                    new_wc[pred] = wcnt[pred]
                    new_deltas[pred] = jnp.full_like(deltas[pred],
                                                     pad_of(deltas[pred]))
                    new_dcounts[pred] = jnp.zeros((), jnp.int32)
            ovf_vec = (jnp.stack(ovfs) if ovfs
                       else jnp.zeros((0,), jnp.bool_))
            bad = jnp.any(ovf_vec) if n_ovf else jnp.array(False)

            def keep(old, new):
                return _select_state(bad, old, new)

            return (keep(w_datas, tuple(new_w[p] for p in s_preds)),
                    keep(w_counts, tuple(new_wc[p] for p in s_preds)),
                    keep(d_datas, tuple(new_deltas[p] for p in s_preds)),
                    keep(d_counts, tuple(new_dcounts[p] for p in s_preds)),
                    rounds + jnp.where(bad, 0, 1),
                    trg + jnp.where(bad, 0, triggers),
                    drv + jnp.where(bad, 0,
                                    sum(new_dcounts[p] for p in s_preds)),
                    ovf_vec)

        def cond(state):
            _, _, _, d_counts, rounds, _, _, ovf_vec = state
            live = sum(d_counts) > 0
            ok = jnp.logical_not(jnp.any(ovf_vec)) if n_ovf else True
            return jnp.logical_and(jnp.logical_and(live, ok),
                                   rounds < max_rounds)

        state = (w_datas, w_counts, d_datas, d_counts, rounds,
                 jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
                 jnp.zeros((n_ovf,), jnp.bool_))
        return jax.lax.while_loop(cond, body, state)

    # loop-state buffers are donated on accelerator backends (exits return
    # the last-good state, so the donated inputs are never needed again)
    return (jax.jit(fn, donate_argnums=(1, 3) if donate else ()),
            ovf_labels)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def materialize_fused(kb, mode: str = "tg", max_rounds: int = 10_000,
                      initial_deltas=None, spill: bool = True):
    """Fused-program materialization of ``kb``.  Returns MatStats, or None
    when the program is outside the fused fragment (the caller falls back to
    the two-phase executor).

    ``initial_deltas`` (pred -> lexsorted Relation of rows ALREADY absorbed
    into the store) switches the driver to incremental mode: round 1 over the
    extensional rules is skipped and the seeded deltas enter the semi-naive
    loop directly — the entry point behind
    ``repro.engine.incremental.materialize_delta``.  Seeded deltas may live
    on EDB predicates, so the loop considers every rule with a live body
    atom, not just the intensional ones (for from-scratch runs the two sets
    coincide: deltas only ever hold derived predicates).

    Capacity overflows retry under a ``RetryBudget``
    (``REPRO_MAX_RETRIES`` / ``REPRO_MAX_RESIDENT_MB``); when the budget is
    exhausted mid-run the driver writes its last-good state back and
    ``spill``s the remaining rounds to the two-phase executor instead of
    doubling buffers toward OOM (``spill=False`` re-raises the
    ``CapacityError`` — tests use it to observe the diagnostic).

    With ``REPRO_CKPT_DIR`` set, the driver checkpoints at every host
    pull boundary (post-ext round, every host-stepped round, every
    fixpoint exit) and resumes from the newest valid checkpoint —
    including checkpoints written by the other executors."""
    from repro.engine.materialize import MatStats
    program = kb.program
    plans = {}
    for rule in program.rules:
        plan = compile_rule_plan(rule, kb.dict)
        if plan is None:
            return None
        plans[id(rule)] = plan

    preds = tuple(sorted(kb.rels))
    use_prefilter = mode == "tg"
    pallas = ops.use_pallas()
    donate = jax.default_backend() != "cpu"
    st = MatStats(mode=mode)
    st.extra["fused"] = True

    # delta-mode lifecycles belong to the caller: no checkpointing there
    ck = recovery.EngineCheckpointer(kb, mode, "fused",
                                     enabled=initial_deltas is None)
    resume = ck.maybe_resume(st)    # replaces kb.dict / kb.rels on success

    # fused precondition: lexsorted, set-semantic stores
    stores, counts = {}, {}
    for p in preds:
        rel = kb.rels[p]
        if rel.count and not rel.is_lexsorted:
            rel = ops.dedup(rel)
        stores[p], counts[p] = rel.data, rel.count
    fp = program_fingerprint((plans[id(r)].key for r in program.rules),
                             sum(counts.values()))
    caps = _Caps(fp, {p: (stores[p], counts[p]) for p in preds},
                 lean=initial_deltas is not None)
    if ck.caps_state is not None:
        caps.adopt(ck.caps_state)   # converged plan from the checkpoint
    for p in preds:
        stores[p] = ops.fit_rows(stores[p], caps.store[p])

    row_bytes = max((kb.rels[p].dtype.itemsize * kb.arities[p]
                     for p in preds), default=8)
    budget = RetryBudget(caps, row_bytes=row_bytes)

    ext_plans = [plans[id(r)] for r in program.extensional_rules()]
    loop_rules = list(program.rules)
    loop_plans = [plans[id(r)] for r in loop_rules]
    deltas: dict = {}           # pred -> (data at planner delta cap, count)
    progressed = resume is not None

    def state_fn():
        """Host-consistent checkpoint payload (single shard): trimmed
        stores, live deltas, and the base facts."""
        payload = {}
        for p in preds:
            payload[f"store__{p}"] = np.asarray(stores[p])[:counts[p]]
        for p, (d, c) in deltas.items():
            rows = np.asarray(d)[:int(c)]
            payload[f"delta__{p}"] = rows[np.lexsort(rows.T[::-1])]
        for p, rel in kb.base.items():
            payload[f"base__{p}"] = rel.np_rows()
        return [payload]

    def run_round(active, delta_preds, is_ext=False):
        nonlocal stores, counts
        prefilter = use_prefilter and not is_ext   # no Def. 23 in round 1
        while True:
            sig = _round_signature(preds, caps, active, delta_preds,
                                   prefilter, pallas)
            fn, ovf_labels, derived = _cached_program(
                sig, lambda: _build_round(preds, caps, active, delta_preds,
                                          prefilter, pallas))
            out = fn(tuple(stores[p] for p in preds),
                     tuple(jnp.int32(counts[p]) for p in preds),
                     tuple(ops.fit_rows(deltas[p][0], caps.delta_cap(p))
                           for p in delta_preds))
            n_stores, n_counts, n_deltas, n_dcounts, trg, ovf_vec = out
            pulled = jax.device_get((n_counts, n_dcounts, trg, ovf_vec))
            ops.HOST_SYNC_STATS.fused_pulls += 1
            cnts, dcnts, trg, ovf = pulled
            if not ovf.any():
                budget.ok()
                stores = dict(zip(preds, n_stores))
                counts = {p: int(c) for p, c in zip(preds, cnts)}
                st.triggers += int(trg)
                new = {}
                for p, d, c in zip(derived, n_deltas, dcnts):
                    st.derived += int(c)
                    if int(c):
                        new[p] = (d, int(c))
                return new
            ops.HOST_SYNC_STATS.fused_retries += 1
            # a rule active at several delta positions repeats its join
            # labels; dedupe so a shared capacity doubles once per retry
            budget.overflow(dict.fromkeys(
                l for f, l in zip(ovf, ovf_labels) if f))
            for p in preds:
                stores[p] = ops.fit_rows(stores[p], caps.store[p])

    def drive():
        nonlocal deltas, progressed
        if resume is not None:
            st.extra["resumed"] = True
            for p, rows in resume.items():
                caps.seed_delta(p, len(rows))
                deltas[p] = (ops.fit_rows(rows, caps.delta_cap(p)),
                             len(rows))
        elif initial_deltas is None:
            # round 1: extensional rules over B
            ext_active = tuple((plan, None) for plan in ext_plans)
            if ext_active:
                deltas = run_round(ext_active, (), is_ext=True)
            st.rounds = 1
            progressed = True
            ck.boundary(st, state_fn, caps=caps)
        else:
            st.extra["delta"] = True
            for p, rel in initial_deltas.items():
                if rel.count:
                    caps.seed_delta(p, rel.count)
                    deltas[p] = (rel.data, rel.count)

        # fixpoint rounds
        while deltas and st.rounds < max_rounds:
            live = tuple(sorted(deltas))
            tail = _linear_tail(loop_plans, live)
            if tail is not None:
                s_preds, active = tail
                o_preds = tuple(p for p in preds if p not in s_preds)
                w = {p: None for p in s_preds}  # sorted tails (data, count)
                while True:
                    sig = _fix_signature(s_preds, o_preds, caps, active,
                                         use_prefilter, pallas, max_rounds,
                                         donate)
                    fn, ovf_labels = _cached_program(
                        sig, lambda: _build_fixpoint(
                            s_preds, o_preds, caps, active, use_prefilter,
                            pallas, max_rounds, donate))
                    out = fn(
                        tuple(stores[p] for p in s_preds),
                        tuple(jnp.array(ops.fit_rows(w[p][0],
                                                     caps.tail_cap(p)))
                              if w[p] else
                              jnp.full((caps.tail_cap(p), kb.arities[p]),
                                       kb.rels[p].pad, kb.rels[p].dtype)
                              for p in s_preds),
                        tuple(jnp.int32(w[p][1] if w[p] else 0)
                              for p in s_preds),
                        tuple(jnp.array(ops.fit_rows(deltas[p][0],
                                                     caps.delta_cap(p)))
                              if p in deltas else
                              jnp.full((caps.delta_cap(p), kb.arities[p]),
                                       kb.rels[p].pad, kb.rels[p].dtype)
                              for p in s_preds),
                        tuple(jnp.int32(deltas[p][1] if p in deltas else 0)
                              for p in s_preds),
                        tuple(stores[p] for p in o_preds),
                        jnp.int32(st.rounds))
                    w_datas, w_counts, d_datas, d_counts, rounds, trg, \
                        drv, ovf_vec = out
                    pulled = jax.device_get((w_counts, d_counts, rounds,
                                             trg, drv, ovf_vec))
                    ops.HOST_SYNC_STATS.fused_pulls += 1
                    wcnts, dcnts, rounds, trg, drv, ovf = pulled
                    prev_rounds = st.rounds
                    st.rounds = int(rounds)
                    st.triggers += int(trg)
                    st.derived += int(drv)
                    deltas = {p: (d, int(c)) for p, d, c in
                              zip(s_preds, d_datas, dcnts) if int(c)}
                    # fold tails into the stores (exits are rare: done, a
                    # full tail, or a capacity retry)
                    ar = kb.arities
                    for p, d, c in zip(s_preds, w_datas, wcnts):
                        w[p] = None
                        if int(c):
                            merged = ops.merge_union(
                                Relation(stores[p], counts[p],
                                         lex_order(ar[p])),
                                Relation(d, int(c), lex_order(ar[p])))
                            counts[p] = merged.count
                            caps.store[p] = max(caps.store[p],
                                                merged.capacity)
                            stores[p] = ops.fit_rows(merged.data,
                                                     caps.store[p])
                    if st.rounds > prev_rounds:
                        budget.ok()     # the loop advanced: real progress
                        progressed = True
                    ck.boundary(st, state_fn, caps=caps)
                    if not ovf.any():
                        deltas = {}
                        break
                    to_double = []
                    for flag, label in zip(ovf, ovf_labels):
                        if not flag:
                            continue
                        if label[0] == "tail" and \
                                int(wcnts[s_preds.index(label[1])]) != 0:
                            # tail-full exit: the fold above made room;
                            # double only when even an empty tail cannot
                            # hold one round's fresh rows
                            continue
                        to_double.append(label)
                    if to_double:
                        ops.HOST_SYNC_STATS.fused_retries += 1
                        budget.overflow(dict.fromkeys(to_double))
                break
            active = tuple((plans[id(r)], j)
                           for r in loop_rules
                           for j, a in enumerate(r.body)
                           if a.pred in deltas)
            if not active:
                break
            deltas = run_round(active, live)
            st.rounds += 1
            progressed = True
            ck.boundary(st, state_fn, caps=caps)

    try:
        drive()
    except CapacityError as e:
        if not spill:
            raise
        if not progressed:
            return None     # cold-start overflow: plain fragment fallback
        # graceful degradation: write the last-good state back and run the
        # remaining rounds on the two-phase executor, whose buffers grow
        # incrementally instead of by whole-plan doubling
        from repro.engine.materialize import _fixpoint_rounds
        for p in preds:
            kb.rels[p] = Relation(stores[p], counts[p],
                                  lex_order(kb.rels[p].arity))
        seed = {}
        for p, (d, c) in deltas.items():
            rows = np.asarray(d)[:int(c)]
            seed[p] = Relation.from_numpy(
                rows[np.lexsort(rows.T[::-1])],
                sorted_by=lex_order(kb.arities[p]))
        st.extra["spilled"] = str(e)
        _fixpoint_rounds(kb, st, seed, mode, max_rounds, ck=ck)
        return st

    for p in preds:
        kb.rels[p] = Relation(stores[p], counts[p],
                              lex_order(kb.rels[p].arity))
    caps.memoize()
    ck.final(st, state_fn, caps=caps)
    return st


# ---------------------------------------------------------------------------
# program lowering for the roofline analysis (no execution)
# ---------------------------------------------------------------------------
def lower_fused_programs(kb, mode: str = "tg"):
    """Lower (without running) the fused executor's programs for ``kb`` at
    the capacity planner's current shapes: ``{name: (hlo_text,
    cost_analysis)}`` for the steady-state round program and — when the
    program has a linear tail — the while_loop fixpoint program.

    This is what ``analysis.roofline`` feeds to the trip-count-aware HLO
    walk to publish bytes/flops-per-fact for the actual executable the
    benchmarks time.  Call it AFTER a real materialization so the capacity
    memo holds converged buckets (the planner then reproduces the shapes
    the timed run compiled at).  Returns None outside the fused fragment."""
    import numpy as np

    program = kb.program
    plans = {}
    for rule in program.rules:
        plan = compile_rule_plan(rule, kb.dict)
        if plan is None:
            return None
        plans[id(rule)] = plan
    preds = tuple(sorted(kb.rels))
    use_prefilter = mode == "tg"
    pallas = ops.use_pallas()
    fp = program_fingerprint((plans[id(r)].key for r in program.rules),
                             sum(kb.rels[p].count for p in preds))
    caps = _Caps(fp, {p: (kb.rels[p].data, kb.rels[p].count) for p in preds})
    loop_plans = [plans[id(r)] for r in program.rules]
    derived = {pl.head_pred for pl in loop_plans}
    active = tuple((plans[id(r)], j) for r in program.rules
                   for j, a in enumerate(r.body) if a.pred in derived)
    if not active:
        return {}

    def rel_aval(cap, p):
        return jax.ShapeDtypeStruct((cap, kb.arities[p]), kb.rels[p].dtype)

    i32 = jax.ShapeDtypeStruct((), np.int32)

    def lowered_pair(fn, *avals):
        compiled = fn.lower(*avals).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0] if cost else {}
        return compiled.as_text(), dict(cost or {})

    out = {}
    delta_in = tuple(sorted({plan.body_preds[jd] for plan, jd in active}))
    fn, _, _ = _build_round(preds, caps, active, delta_in, use_prefilter,
                            pallas)
    out["round"] = lowered_pair(
        fn,
        tuple(rel_aval(caps.store[p], p) for p in preds),
        tuple(i32 for _ in preds),
        tuple(rel_aval(caps.delta_cap(p), p) for p in delta_in))
    # the fixpoint's steady-state live set is usually smaller than the
    # early-round one (aux predicates quiesce): fall back to singleton live
    # sets so the lowered fixpoint matches the phase the driver actually
    # spends its time in
    tail = _linear_tail(loop_plans, delta_in)
    if tail is None:
        for p in sorted(derived):
            tail = _linear_tail(loop_plans, (p,))
            if tail is not None:
                break
    if tail is not None:
        s_preds, t_active = tail
        o_preds = tuple(p for p in preds if p not in s_preds)
        ffn, _ = _build_fixpoint(s_preds, o_preds, caps, t_active,
                                 use_prefilter, pallas, 10_000, False)
        out["fixpoint"] = lowered_pair(
            ffn,
            tuple(rel_aval(caps.store[p], p) for p in s_preds),
            tuple(rel_aval(caps.tail_cap(p), p) for p in s_preds),
            tuple(i32 for _ in s_preds),
            tuple(rel_aval(caps.delta_cap(p), p) for p in s_preds),
            tuple(i32 for _ in s_preds),
            tuple(rel_aval(caps.store[p], p) for p in o_preds),
            i32)
    return out
