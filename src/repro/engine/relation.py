"""Padded fixed-capacity relations (int32 column tensors) with pow-2 capacity
bucketing: the XLA-compatible representation of GLog's columnar tables.

A ``Relation`` holds ``data`` (capacity, arity) int32 and a fill ``count``.
Rows past ``count`` are padding (PAD).  All engine ops are shape-stable; data-
dependent output sizes use a jitted count pass + host-side pow-2 bucket choice
+ a jitted materialize pass (bounded recompilation).

Sortedness invariant
--------------------
``sorted_by`` records the column order by which the valid rows are known to
be lexicographically sorted (``None`` = unknown).  A full lexsort (primary
column 0, then 1, ...) is ``tuple(range(arity))``; a single-key sort from
``ops.sort_by`` is ``(key_col,)``.  Ops that only drop or keep rows in place
(filter/compact/antijoin) preserve the marker; ops that reorder or merge
establish or clear it.  ``EngineKB`` keeps every store relation fully
lexsorted so dedup/antijoin can skip their sort pass and unions become
incremental sorted merges.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD = jnp.iinfo(jnp.int32).max


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n - 1).bit_length())


def lex_order(arity: int) -> Tuple[int, ...]:
    """The ``sorted_by`` marker of a fully lexsorted relation."""
    return tuple(range(arity))


@dataclass
class Relation:
    data: jax.Array          # (capacity, arity) int32, rows >= count are PAD
    count: int               # python int (host-side fill level)
    sorted_by: Optional[Tuple[int, ...]] = None  # known sort order, or None

    @property
    def capacity(self):
        return self.data.shape[0]

    @property
    def arity(self):
        return self.data.shape[1]

    @property
    def is_lexsorted(self) -> bool:
        """True iff the relation carries the full-lexsort marker."""
        return self.sorted_by == lex_order(self.arity)

    def np_rows(self):
        return np.asarray(self.data[:self.count])

    @staticmethod
    def from_numpy(rows: np.ndarray, capacity: int = 0,
                   sorted_by: Optional[Tuple[int, ...]] = None) -> "Relation":
        n = rows.shape[0]
        cap = max(next_pow2(n), 1, capacity)
        arity = rows.shape[1] if rows.ndim == 2 else 1
        data = np.full((cap, arity), np.iinfo(np.int32).max, np.int32)
        if n:
            data[:n] = rows
        return Relation(jnp.asarray(data), n, sorted_by)

    @staticmethod
    def empty(arity: int, capacity: int = 1) -> "Relation":
        # an empty relation is trivially sorted by any order
        return Relation(jnp.full((max(capacity, 1), arity), PAD, jnp.int32),
                        0, lex_order(arity))

    def rows_set(self):
        return {tuple(int(x) for x in r) for r in self.np_rows()}
