"""Padded fixed-capacity relations (narrow-dtype column tensors) with pow-2
capacity bucketing: the XLA-compatible representation of GLog's columnar
tables.

A ``Relation`` holds ``data`` (capacity, arity) integer rows and a fill
``count``.  Rows past ``count`` are padding (the dtype's max value).  All
engine ops are shape-stable; data-dependent output sizes use a jitted count
pass + host-side pow-2 bucket choice + a jitted materialize pass (bounded
recompilation).

Store dtype
-----------
The store dtype is configurable (``REPRO_STORE_DTYPE``: ``int16`` /
``int32`` (default) / ``int64``) and threads end-to-end through the engine:
dictionary ids, relation columns, the sort/merge/probe cores, and the
capacity planner's padded buffers all carry it.  Narrower rows halve the
memory traffic of the three ops that dominate at scale (sort, merge_union,
probe) and halve the padded-buffer footprint the capacity planner
allocates; ``int64`` is kept as the wide A/B baseline for the scale
benchmarks (it requires a process with ``JAX_ENABLE_X64=1`` — x64-off jax
silently canonicalizes int64 arrays to int32).  The PAD sentinel is always
the dtype's max value, so lex-max padding invariants are dtype-independent;
the dictionary reserves it (ids must stay strictly below PAD).

Sortedness invariant
--------------------
``sorted_by`` records the column order by which the valid rows are known to
be lexicographically sorted (``None`` = unknown).  A full lexsort (primary
column 0, then 1, ...) is ``tuple(range(arity))``; a single-key sort from
``ops.sort_by`` is ``(key_col,)``.  Ops that only drop or keep rows in place
(filter/compact/antijoin) preserve the marker; ops that reorder or merge
establish or clear it.  ``EngineKB`` keeps every store relation fully
lexsorted so dedup/antijoin can skip their sort pass and unions become
incremental sorted merges.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# legacy alias: the PAD sentinel of the default (int32) store dtype.  Dtype-
# generic code must use ``pad_value``/``pad_of`` instead.
PAD = jnp.iinfo(jnp.int32).max

STORE_DTYPES = {
    "int16": np.dtype(np.int16),
    "int32": np.dtype(np.int32),
    "int64": np.dtype(np.int64),
}


def store_dtype() -> np.dtype:
    """The process-default store dtype (``REPRO_STORE_DTYPE``, default
    int32).  int64 stores need an x64-enabled jax process: with the global
    x64 flag off, jax canonicalizes int64 arrays to int32 at creation, which
    would silently narrow the "wide" A/B baseline back to int32."""
    name = os.environ.get("REPRO_STORE_DTYPE", "int32")
    dt = STORE_DTYPES.get(name)
    if dt is None:
        raise ValueError(f"REPRO_STORE_DTYPE={name!r}: expected one of "
                         f"{sorted(STORE_DTYPES)}")
    if dt == np.int64 and not jax.config.jax_enable_x64:
        raise RuntimeError(
            "REPRO_STORE_DTYPE=int64 requires an x64-enabled jax process "
            "(set JAX_ENABLE_X64=1 before jax is imported); otherwise jax "
            "canonicalizes the int64 store back to int32")
    return dt


def pad_value(dtype) -> int:
    """The PAD sentinel of a store dtype: its max value (lex-maximal, so PAD
    rows sort last under every comparator the engine uses)."""
    return int(np.iinfo(np.dtype(dtype)).max)


def pad_of(data) -> int:
    """PAD sentinel for an array's dtype (python int: usable as a fill value
    or weak-typed comparison scalar inside traced code)."""
    return pad_value(data.dtype)


def id_range(dtype) -> Tuple[int, int]:
    """(min, max) dictionary-id range representable in a store dtype: the
    PAD sentinel (dtype max) is reserved, negative ids are skolem nulls."""
    info = np.iinfo(np.dtype(dtype))
    return int(info.min), int(info.max) - 1


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n - 1).bit_length())


def lex_order(arity: int) -> Tuple[int, ...]:
    """The ``sorted_by`` marker of a fully lexsorted relation."""
    return tuple(range(arity))


@dataclass
class Relation:
    data: jax.Array          # (capacity, arity) ints, rows >= count are PAD
    count: int               # python int (host-side fill level)
    sorted_by: Optional[Tuple[int, ...]] = None  # known sort order, or None

    @property
    def capacity(self):
        return self.data.shape[0]

    @property
    def arity(self):
        return self.data.shape[1]

    @property
    def dtype(self) -> np.dtype:
        return np.dtype(self.data.dtype)

    @property
    def pad(self) -> int:
        return pad_value(self.data.dtype)

    @property
    def is_lexsorted(self) -> bool:
        """True iff the relation carries the full-lexsort marker."""
        return self.sorted_by == lex_order(self.arity)

    def np_rows(self):
        return np.asarray(self.data[:self.count])

    @staticmethod
    def from_numpy(rows: np.ndarray, capacity: int = 0,
                   sorted_by: Optional[Tuple[int, ...]] = None,
                   dtype=None) -> "Relation":
        """Build a padded relation from host rows.

        ``dtype``: target store dtype — defaults to the rows' own dtype when
        that is a supported store dtype, else the process default.  A
        narrowing conversion range-checks the rows and raises
        ``OverflowError`` instead of silently corrupting keys."""
        rows = np.asarray(rows)
        if dtype is None:
            if rows.dtype in STORE_DTYPES.values():
                dtype = rows.dtype
            else:
                dtype = store_dtype()
        dtype = np.dtype(dtype)
        n = rows.shape[0]
        if n and rows.dtype != dtype and np.issubdtype(rows.dtype,
                                                       np.integer):
            lo, hi = id_range(dtype)
            rmin, rmax = int(rows.min()), int(rows.max())
            if rmin < lo or rmax > hi:
                raise OverflowError(
                    f"rows [{rmin}, {rmax}] exceed the {dtype} store id "
                    f"range [{lo}, {hi}]")
        cap = max(next_pow2(n), 1, capacity)
        arity = rows.shape[1] if rows.ndim == 2 else 1
        data = np.full((cap, arity), pad_value(dtype), dtype)
        if n:
            data[:n] = rows
        return Relation(jnp.asarray(data), n, sorted_by)

    @staticmethod
    def empty(arity: int, capacity: int = 1, dtype=None) -> "Relation":
        dtype = np.dtype(dtype) if dtype is not None else store_dtype()
        # an empty relation is trivially sorted by any order
        return Relation(jnp.full((max(capacity, 1), arity),
                                 pad_value(dtype), dtype),
                        0, lex_order(arity))

    def rows_set(self):
        return {tuple(int(x) for x in r) for r in self.np_rows()}
