"""Padded fixed-capacity relations (int32 column tensors) with pow-2 capacity
bucketing: the XLA-compatible representation of GLog's columnar tables.

A ``Relation`` holds ``data`` (capacity, arity) int32 and a fill ``count``.
Rows past ``count`` are padding (PAD).  All engine ops are shape-stable; data-
dependent output sizes use a jitted count pass + host-side pow-2 bucket choice
+ a jitted materialize pass (bounded recompilation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

PAD = jnp.iinfo(jnp.int32).max


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n - 1).bit_length())


@dataclass
class Relation:
    data: jax.Array          # (capacity, arity) int32, rows >= count are PAD
    count: int               # python int (host-side fill level)

    @property
    def capacity(self):
        return self.data.shape[0]

    @property
    def arity(self):
        return self.data.shape[1]

    def np_rows(self):
        return np.asarray(self.data[:self.count])

    @staticmethod
    def from_numpy(rows: np.ndarray, capacity: int = 0) -> "Relation":
        n = rows.shape[0]
        cap = max(next_pow2(n), 1, capacity)
        arity = rows.shape[1] if rows.ndim == 2 else 1
        data = np.full((cap, arity), np.iinfo(np.int32).max, np.int32)
        if n:
            data[:n] = rows
        return Relation(jnp.asarray(data), n)

    @staticmethod
    def empty(arity: int, capacity: int = 1) -> "Relation":
        return Relation(jnp.full((max(capacity, 1), arity), PAD, jnp.int32), 0)

    def rows_set(self):
        return {tuple(int(x) for x in r) for r in self.np_rows()}
