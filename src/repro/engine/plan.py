"""Backend-neutral rule-plan IR: *what* a materialization round computes.

A :class:`RulePlan` is the static, trace-time description of one Datalog
rule — per-atom filters, the Def. 23 antijoin pre-restriction slot, the
left-deep join chain, and the head projection — with a pure-python ``key``
fingerprint.  ``compile_rule_plan`` builds one (or ``None`` for rules
outside the plannable fragment: existentials, disconnected bodies).

The plan describes what a round computes; *where* it runs is an executor
choice.  Three physical executors consume the same programs:

* the two-phase executor (``repro.engine.materialize``) — the reference
  path (it interprets rules directly; one blocking host pull per primitive),
* the fused round executor (``repro.engine.fused``) — one jitted XLA
  program per round on a single device,
* the distributed executor (``repro.engine.distributed``) — the same plan
  walk inside ``shard_map`` over hash-partitioned shards, with fixed-
  capacity bucket exchanges at the pre-restriction / join / absorb
  boundaries.

This module also owns the capacity + overflow contract the compiled
executors share:

* :class:`_Caps` pre-sizes every planned buffer (store / delta / tail /
  join / exchange bucket) before a program is compiled, and memoizes
  successful sizes per :func:`program_fingerprint` in the module-level
  ``_CAP_MEMO`` so warmed-up programs plan right first try.
* Every planned capacity gets an in-program overflow flag (``needed >
  planned``).  When any flag fires, the executor discards the round's
  outputs, doubles exactly the overflowed capacities
  (``_Caps.double(label)``), recompiles, and retries the same round from
  inputs it still holds.  Labels are ``(kind, name)`` pairs; an executor
  must emit its flags in exactly the order it enumerates its labels.
* :func:`_cached_program` is the shared bounded FIFO compile cache keyed
  by each executor's full static signature.

``_exec_rule_traced`` / ``_absorb_traced`` are the traced round pieces
built from the ``repro.engine.ops`` cores.  The optional ``route`` hook
lets the distributed executor insert a bucket exchange before the Def. 23
pre-restriction and before both sides of every join without duplicating
the chain walk.

The *linear-tail fixpoint* plumbing both compiled executors share also
lives here: :func:`_linear_tail` decides when the remaining fixpoint is
linear (every still-reachable rule has exactly one body atom over a
still-changing predicate) so a whole phase can run inside one
``lax.while_loop``, and :func:`_select_state` is the loop-carry select
that keeps the last GOOD state when an overflow flag fires mid-loop (the
loop exits with it; the host doubles capacities and resumes — the
fixpoint never restarts).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.terms import is_var
from repro.engine import faultinject, ops
from repro.engine.relation import next_pow2, pad_of


def max_retries() -> int:
    """Attempt ceiling of one overflow double-and-retry ladder
    (``REPRO_MAX_RETRIES``): consecutive zero-progress retries past this
    raise :class:`CapacityError` instead of doubling toward OOM."""
    return int(os.environ.get("REPRO_MAX_RETRIES", "8"))


def max_resident_bytes() -> int:
    """Resident-footprint ceiling for the planner's padded buffers
    (``REPRO_MAX_RESIDENT_MB``, default 8192): doubling past it raises
    :class:`CapacityError` — the executor degrades to the two-phase
    spill path instead of asking XLA for buffers that cannot fit."""
    return int(os.environ.get("REPRO_MAX_RESIDENT_MB", "8192")) << 20


class CapacityError(RuntimeError):
    """A capacity ladder ran out of budget.  Names the bucket label being
    grown and the bytes the next plan would have resided at, so the
    operator (or the spill path) knows which buffer diverged."""

    def __init__(self, label, requested_bytes: int, attempts: int,
                 reason: str):
        self.label = label
        self.requested_bytes = int(requested_bytes)
        self.attempts = attempts
        self.reason = reason
        super().__init__(
            f"capacity bucket {label!r} exhausted its retry budget after "
            f"{attempts} attempts ({reason}); the plan would reside at "
            f"~{self.requested_bytes >> 20} MiB "
            f"({self.requested_bytes} bytes). Raise REPRO_MAX_RETRIES / "
            "REPRO_MAX_RESIDENT_MB, or let the driver spill to the "
            "two-phase executor.")


class RetryBudget:
    """Bounded replacement for the unbounded double-and-retry loops.

    One budget guards one driver invocation.  ``overflow(labels)`` records
    a failed attempt and grows exactly the overflowed capacities; ``ok()``
    marks progress (a committed round / a fixpoint exit that advanced) and
    resets the attempt ladder.  Growth escalates: the first two consecutive
    overflows of a label double it once each (the legacy trajectory, so
    warm capacity plans and their memoized sizes are unchanged), then
    ramps to at most four doublings (x16) per attempt — a buffer that
    keeps overflowing reaches any realizable size within the default 8
    attempts instead of creeping one doubling at a time, while the final
    jump overshoots the converged plan by a bounded factor instead of
    squaring past it.

    Two ceilings end the ladder with a diagnostic :class:`CapacityError`:
    ``REPRO_MAX_RETRIES`` consecutive zero-progress attempts, or a planned
    resident footprint past ``REPRO_MAX_RESIDENT_MB``."""

    def __init__(self, caps: "_Caps", row_bytes: int = 8,
                 attempts: int | None = None,
                 resident_bytes: int | None = None):
        self.caps = caps
        self.row_bytes = max(int(row_bytes), 1)
        self.max_attempts = max_retries() if attempts is None else attempts
        self.max_bytes = (max_resident_bytes() if resident_bytes is None
                          else resident_bytes)
        self._attempts = 0
        self._streak: dict = {}

    def ok(self) -> None:
        self._attempts = 0
        self._streak.clear()

    def resident_bytes(self) -> int:
        return self.caps.planned_rows() * self.row_bytes

    def overflow(self, labels) -> None:
        """Record one failed attempt; double every overflowed label (with
        escalation); raise :class:`CapacityError` when the budget is
        spent."""
        labels = list(labels)
        self._attempts += 1
        worst = labels[0] if labels else ("unknown", "?")
        if self._attempts > self.max_attempts:
            raise CapacityError(worst, self.resident_bytes(),
                                self._attempts - 1,
                                f"REPRO_MAX_RETRIES={self.max_attempts} "
                                "zero-progress retries")
        stale = set(self._streak) - set(labels)
        for label in stale:
            del self._streak[label]
        for label in labels:
            streak = self._streak.get(label, 0) + 1
            self._streak[label] = streak
            doubles = 1 if streak <= 2 else min(1 << (streak - 2), 4)
            for _ in range(doubles):
                self.caps.double(label)
        resident = self.resident_bytes()
        if resident > self.max_bytes:
            raise CapacityError(worst, resident, self._attempts,
                                "planned buffers exceed "
                                "REPRO_MAX_RESIDENT_MB")


# successful planner capacities keyed by (program fingerprint, kind, name) —
# reused across EngineKB instances so a warmed-up program never re-learns
# its buckets (benchmarks warm on the same instance they time)
_CAP_MEMO: dict = {}
_CAP_MEMO_LIMIT = 8192

# compiled round / fixpoint programs keyed by their full static signature;
# bounded FIFO so superseded capacity plans don't strand XLA executables
# forever in long-lived processes
_COMPILE_CACHE: dict = {}
_COMPILE_CACHE_LIMIT = 128


def _cached_program(sig, build):
    prog = _COMPILE_CACHE.get(sig)
    if prog is None:
        while len(_COMPILE_CACHE) >= _COMPILE_CACHE_LIMIT:
            _COMPILE_CACHE.pop(next(iter(_COMPILE_CACHE)))
        prog = _COMPILE_CACHE[sig] = build()
    return prog


def program_fingerprint(plan_keys, total_count):
    """Capacity-memo key for one (program, instance scale): the rule plan
    keys plus the pow-2 bucket of the instance size, so converged capacities
    transfer across runs of the same program at the same scale."""
    return (tuple(plan_keys), next_pow2(max(int(total_count), 1)))


# ---------------------------------------------------------------------------
# static rule plans
# ---------------------------------------------------------------------------
class RulePlan:
    """Trace-time description of one Datalog rule: per-atom filters, the
    Def. 23 pre-restriction slot, the left-deep join chain, and the head
    projection.  ``key`` is a pure-python fingerprint used for compile-cache
    and capacity-memo keys."""

    def __init__(self, rule, dic):
        from repro.engine.materialize import _atom_filters
        self.head_pred = rule.head.pred
        self.body_preds = tuple(a.pred for a in rule.body)
        self.atoms = []            # (eq_pairs, const_pairs) per body atom
        self.joins = []            # (lkey in cur, rkey in atom, eq2) per join
        var_col: dict = {}
        width = 0
        self.ok = not rule.existentials
        for j, atom in enumerate(rule.body):
            eq, consts, vc = _atom_filters(atom, dic)
            self.atoms.append((eq, consts))
            if j == 0:
                var_col = dict(vc)
                width = atom.arity
                continue
            shared = [v for v in vc if v in var_col]
            if not shared:
                self.ok = False    # disconnected body -> cross join, not fused
                break
            v0 = shared[0]
            eq2 = tuple((var_col[v], width + vc[v]) for v in shared[1:])
            self.joins.append((var_col[v0], vc[v0], eq2))
            for v, c in vc.items():
                var_col.setdefault(v, width + c)
            width += atom.arity
        # Def. 23 pre-restriction: first body atom whose own columns
        # determine the full head tuple (same choice as execute_rule)
        self.pre = None
        if self.ok:
            for j, a in enumerate(rule.body):
                _, _, vc = _atom_filters(a, dic)
                if rule.head.args and all(is_var(t) and t in vc
                                          for t in rule.head.args):
                    self.pre = (j, tuple(vc[t] for t in rule.head.args))
                    break
            self.head_spec = tuple(
                ("col", var_col[t]) if is_var(t) else ("const", dic.encode(t))
                for t in rule.head.args)
            self.key = (self.head_pred, self.body_preds, tuple(self.atoms),
                        tuple(self.joins), self.pre, self.head_spec)


def compile_rule_plan(rule, dic):
    """Build the static plan for one rule, or None if the rule is outside
    the plannable fragment (existentials / disconnected body)."""
    plan = RulePlan(rule, dic)
    return plan if plan.ok else None


# ---------------------------------------------------------------------------
# linear-tail fixpoint plumbing (shared by the fused and distributed
# while_loop fixpoint programs)
# ---------------------------------------------------------------------------
def _linear_tail(intens_plans, live_preds):
    """If every rule still reachable from the live deltas has exactly one
    body atom over a still-changing predicate, the remaining fixpoint is
    linear: return (changing predicate set S, [(plan, delta_pos)]).  Else
    None, and the driver keeps stepping host-driven rounds."""
    S = set(live_preds)
    while True:
        add = {p.head_pred for p in intens_plans
               if any(bp in S for bp in p.body_preds)} - S
        if not add:
            break
        S |= add
    active = []
    for plan in intens_plans:
        hits = [j for j, bp in enumerate(plan.body_preds) if bp in S]
        if not hits:
            continue
        if len(hits) != 1:
            return None
        active.append((plan, hits[0]))
    return (tuple(sorted(S)), tuple(active)) if active else None


def _select_state(bad, old, new):
    """Loop-carry select: keep ``old`` (the last good state) wherever the
    scalar ``bad`` flag is set, else adopt ``new``.  ``old``/``new`` are
    matching pytrees of arrays."""
    return jax.tree_util.tree_map(lambda o, n: jnp.where(bad, o, n),
                                  old, new)


# ---------------------------------------------------------------------------
# traced pieces (built from the ops cores; no host interaction)
# ---------------------------------------------------------------------------
def _project_head_core(data, spec):
    cols = []
    for kind, v in spec:
        if kind == "col":
            cols.append(data[:, v])
        else:
            cols.append(jnp.full((data.shape[0],), v, data.dtype))
    valid = data[:, 0] != pad_of(data)
    return jnp.where(valid[:, None], jnp.stack(cols, axis=1), pad_of(data))


def _exec_rule_traced(plan, inputs, pre_data, join_caps, pallas,
                      prefilter=None, route=None):
    """One rule body over pre-sized inputs.  ``inputs`` are lexsorted padded
    blocks (stores / deltas — the sorted-store invariant is the compiled
    executors' precondition), so primary-column join keys need no sort.  The
    Def. 23 pre-restriction either antijoins against ``pre_data`` (one
    haystack) or calls the ``prefilter(rows, cols) -> keep_mask`` hook (the
    fused fixpoint loop probes store | tail; the distributed fixpoint loop
    probes the canonical-home store | tail shard, so ``route`` and
    ``prefilter`` compose — rows are re-partitioned by projected-head hash
    FIRST, landing each candidate on the shard that owns the would-be head
    fact).  When ``route`` is given (the distributed executor), rows are
    re-partitioned before the pre-restriction and before both sides of each
    join — ``route(rows, key_cols, tag) -> (rows', [overflow_flags],
    sort_key)`` — where ``sort_key`` is the statically-known sort column of
    the returned block (``None`` when unknown: the chain re-sorts; the
    distributed fixpoint pre-sorts hoisted and software-pipelined routed
    blocks outside the loop body and returns the join key here).
    Returns (head_rows, triggers, overflow_flags); the flag order is pre /
    left / right exchange flags then the join-capacity flag, per join step
    (executors enumerate matching labels statically)."""
    ovfs = []
    cur = None
    cur_skey = None                # statically-known sort column of cur
    for j, (eq, consts) in enumerate(plan.atoms):
        data = inputs[j]
        data_skey = 0              # inputs arrive lexsorted (primary col 0)
        if eq or consts:
            mask = ops.filter_mask_core(data, eq, consts)
            data = ops.compact_core(data, mask, data.shape[0])
        if plan.pre is not None and plan.pre[0] == j and (
                pre_data is not None or prefilter is not None):
            if route is not None:
                # routed by projected-head hash: each candidate lands on
                # the canonical-home shard of its would-be head fact, so
                # the antijoin / prefilter probe is purely local
                data, flags, data_skey = route(data, plan.pre[1],
                                               ("pre", j))
                ovfs += flags
            if prefilter is not None:
                keep = prefilter(data, plan.pre[1])
            else:
                keep = ops.anti_keep_core(data, pre_data, plan.pre[1],
                                          pallas=pallas)
            data = ops.compact_core(data, keep, data.shape[0])
        if cur is None:
            cur, cur_skey = data, data_skey
            continue
        lk, rk, eq2 = plan.joins[j - 1]
        if route is not None:
            cur, flags, cur_skey = route(cur, (lk,), ("jl", j))
            ovfs += flags
            data, flags, data_skey = route(data, (rk,), ("jr", j))
            ovfs += flags
        ls = cur if cur_skey == lk else ops.keysort_core(cur, lk,
                                                         pallas=pallas)
        rs = data if data_skey == rk else ops.keysort_core(data, rk,
                                                           pallas=pallas)
        total, per, cum, lo = ops.join_count_core(ls, rs, lk, rk)
        cap = join_caps[j - 1]
        ovfs.append(total > cap)
        cur = ops.join_gather_core(ls, rs, per, cum, lo, total, cap)
        cur_skey = lk              # output rows follow ls's key order
        if eq2:
            mask = ops.filter_mask_core(cur, eq2, ())
            cur = ops.compact_core(cur, mask, cap)
    triggers = jnp.sum(cur[:, 0] != pad_of(cur)).astype(jnp.int32)
    return _project_head_core(cur, plan.head_spec), triggers, ovfs


def _absorb_traced(heads, fresh_mask_fn, into_data, into_count, delta_cap,
                   pallas, presorted=False):
    """Round-level redundancy filtering + merge for one predicate: concat
    rule outputs, lexsort + first-occurrence dedup, keep rows passing
    ``fresh_mask_fn`` (non-membership in the store — or in store | tail
    inside the fused fixpoint loop), compact the fresh rows to the delta
    bucket, and fold them into ``into_data`` (the store, or the loop's tail
    buffer) with the incremental sorted merge.  ``presorted`` lets a caller
    that already holds ONE lexsorted head block (the distributed fixpoint's
    sorted absorb exchange) skip the O(n log n) sort.  Returns
    (merged, new_count, delta, n_fresh, (delta_overflow, merge_overflow))."""
    cat = heads[0] if len(heads) == 1 else jnp.concatenate(heads, axis=0)
    s = cat if presorted and len(heads) == 1 else ops.lexsort_core(
        cat, pallas=pallas)
    uniq = ops.dedup_mask_core(s, pallas=pallas)
    fresh_mask = jnp.logical_and(uniq, fresh_mask_fn(s))
    n_fresh = jnp.sum(fresh_mask).astype(jnp.int32)
    delta = ops.compact_core(s, fresh_mask, delta_cap)
    new_count = into_count + n_fresh
    merged = ops.merge_core(into_data, delta, into_count, n_fresh)
    return (merged, new_count, delta, n_fresh,
            (n_fresh > delta_cap, new_count > into_data.shape[0]))


# ---------------------------------------------------------------------------
# capacity planner
# ---------------------------------------------------------------------------
class _Caps:
    """Pre-sizes every planned buffer; doubles on overflow; memoizes
    successful sizes per program fingerprint.

    Capacity kinds: per-predicate ``store`` / ``delta`` / ``tail`` buckets,
    per-join-step ``join`` output buckets, and per-exchange-site ``bucket``
    capacities (distributed executor: the per-destination bucket of one
    ``_exchange`` call; the received block is ``ndev * bucket`` rows).  For
    the distributed executor all counts (and hence all planned capacities)
    are per shard."""

    def __init__(self, fp, stores, ndev: int = 1, lean: bool = False):
        """``lean`` starts the delta-family guesses at the floor instead of
        ~2x the store scale: incremental-maintenance calls enter with deltas
        of a few rows, and from-scratch-sized delta/tail/join buffers make
        every fixpoint iteration pay O(store)-scale sorts for O(|delta|)
        work (measured ~20x slower per iteration).  Overflow doubling still
        grows them when a cascade turns out deep; memoized capacities
        dominate either guess."""
        self.fp = fp
        base = max([c for _, c in stores.values()] + [1])
        if lean or faultinject.get_faults().tiny_caps():
            # forced-overflow storm (REPRO_FAULT_SPEC=storm): start the
            # delta-family guesses at the floor so every cold phase pays
            # the full double-and-retry ladder
            base = 1
        self.store = {}
        self.delta = {}
        self.tail = {}
        self.join = {}
        self.bucket = {}
        for pred, (data, count) in stores.items():
            # converged capacities from a previous run of this program
            # dominate the cold-start guess (guesses must not drift upward
            # with the memoized sizes, or every run re-plans and recompiles)
            memo = _CAP_MEMO.get((fp, "store", pred), 0)
            guess = memo or next_pow2(max(32, 4 * max(count, 1)))
            self.store[pred] = max(guess, next_pow2(max(count, 1)))
        self._delta_guess = next_pow2(max(64, 2 * base))
        self._bucket_guess = next_pow2(max(32, 2 * base // max(ndev, 1)))

    def delta_cap(self, pred):
        if pred not in self.delta:
            self.delta[pred] = (_CAP_MEMO.get((self.fp, "delta", pred), 0)
                                or self._delta_guess)
        return self.delta[pred]

    def join_cap(self, plan, idx):
        key = (plan.key, idx)
        if key not in self.join:
            self.join[key] = (_CAP_MEMO.get((self.fp, "join", key), 0)
                              or next_pow2(max(64, 2 * self._delta_guess)))
        return self.join[key]

    def tail_cap(self, pred):
        """Sorted-tail bucket for the fused fixpoint loop: new facts
        accumulate here (O(tail) merges per iteration instead of O(store))
        until it fills and the host folds it into the store."""
        if pred not in self.tail:
            self.tail[pred] = (_CAP_MEMO.get((self.fp, "tail", pred), 0)
                               or 4 * self.delta_cap(pred))
        return self.tail[pred]

    def bucket_cap(self, key):
        """Per-destination bucket of one distributed exchange site."""
        if key not in self.bucket:
            self.bucket[key] = (_CAP_MEMO.get((self.fp, "bucket", key), 0)
                                or self._bucket_guess)
        return self.bucket[key]

    def seed_delta(self, pred, count):
        """Widen ``pred``'s delta bucket to hold an externally-seeded delta.
        Incremental materialization enters the round loop with insertions as
        the FIRST delta (not a round output sized by an overflow flag), so
        the seed must fit a priori — memoized capacities still dominate when
        they are already large enough."""
        self.delta[pred] = max(self.delta_cap(pred),
                               next_pow2(max(int(count), 1)))
        return self.delta[pred]

    def double(self, label):
        kind, name = label
        if kind == "store":
            self.store[name] *= 2
        elif kind == "delta":
            self.delta[name] *= 2
        elif kind == "tail":
            self.tail[name] *= 2
        elif kind == "bucket":
            self.bucket[name] *= 2
        else:
            self.join[name] *= 2

    def planned_rows(self) -> int:
        """Total planned buffer rows across every capacity kind touched so
        far — the padded-buffer footprint an executor allocates is this
        times arity times the store dtype's itemsize, which is what the
        narrow-dtype store halves."""
        return (sum(self.store.values()) + sum(self.delta.values())
                + sum(self.tail.values()) + sum(self.join.values())
                + sum(self.bucket.values()))

    def state(self) -> dict:
        """Checkpointable snapshot of every converged capacity (plain
        dicts of pow-2 sizes keyed by the planner's own label names)."""
        return {"store": dict(self.store), "delta": dict(self.delta),
                "tail": dict(self.tail), "join": dict(self.join),
                "bucket": dict(self.bucket)}

    def adopt(self, state: dict | None) -> None:
        """Overlay a checkpointed capacity plan: every saved size floors
        the current one (sizes only grow, so a resumed run plans at least
        as large as the crashed run had converged to and re-pays no
        overflow ladder).  Keys are the planner's own label names — plan
        keys are deterministic for a given program + dictionary prefix,
        so they round-trip through pickle across processes."""
        if not state:
            return
        for kind in ("store", "delta", "tail", "join", "bucket"):
            mine = getattr(self, kind)
            for name, cap in state.get(kind, {}).items():
                mine[name] = max(mine.get(name, 0), int(cap))

    def memoize(self):
        while len(_CAP_MEMO) >= _CAP_MEMO_LIMIT:
            _CAP_MEMO.pop(next(iter(_CAP_MEMO)))
        for pred, cap in self.store.items():
            _CAP_MEMO[(self.fp, "store", pred)] = cap
        for pred, cap in self.delta.items():
            _CAP_MEMO[(self.fp, "delta", pred)] = cap
        for pred, cap in self.tail.items():
            _CAP_MEMO[(self.fp, "tail", pred)] = cap
        for key, cap in self.join.items():
            _CAP_MEMO[(self.fp, "join", key)] = cap
        for key, cap in self.bucket.items():
            _CAP_MEMO[(self.fp, "bucket", key)] = cap
