"""Durable checkpointing + crash recovery for the materialization engine.

The train side already survives preemption (``repro.train.checkpoint`` /
``repro.train.fault``); this module gives the KB engine the same story at
materialization-round granularity.  Set ``REPRO_CKPT_DIR`` and every
executor — two-phase, fused, distributed — checkpoints its host-consistent
state at round/phase boundaries and resumes from the newest valid
checkpoint on the next run.

Checkpoint layout (one directory per tag, tag = completed-round cursor)::

    <REPRO_CKPT_DIR>/ckpt_00000042/
        shard_0.npz        per-shard payload: store__<pred> / delta__<pred>
        shard_1.npz        valid rows (trimmed, lexsorted per shard);
        ...                base__<pred> rides shard 0
        dict.pkl           Dictionary.state_dict() (term <-> id interning)
        caps.pkl           _Caps.state() (converged capacity plan)
        MANIFEST.json      tag + run meta + sha256 per payload file

Atomicity and integrity: payloads are written into a ``.tmp`` sibling,
the manifest (with content checksums) is written and fsynced LAST, and the
directory is atomically renamed into place — a crash mid-save leaves
either the previous checkpoint or a ``.tmp`` directory the loader ignores.
On load, every file is re-hashed against the manifest; a corrupt or
half-written checkpoint is skipped and the next-newest valid one is used.

Executor neutrality and elasticity: checkpointed state is *host* data —
trimmed rows, the dictionary, the round cursor — with no device placement
baked in.  A run checkpointed by the distributed executor at ndev=4
restores into the fused executor, the two-phase executor, or a dist run
at any other ndev: the loader concatenates the per-shard rows and the
restoring executor re-partitions by the same full-tuple hash its
exchanges use (``distributed.np_tuple_hash``), so every fact lands back
on its canonical home for the new mesh shape.

Resume correctness: semi-naive restart from a partially-materialized
store alone would terminate immediately (everything already derived in
earlier rounds is IN the store, so round one's "fresh" set is empty) —
checkpoints therefore persist the LIVE DELTAS next to the stores, and
``maybe_resume`` hands them back as the seed of the continued fixpoint.

``PreemptionGuard`` integration: when checkpointing is enabled the
engine installs a chained SIGTERM guard; the flag is polled at the same
boundaries (never mid-program), the executor saves a final consistent
checkpoint and exits with status 143.

Fault rehearsal: every boundary also consults ``repro.engine.faultinject``
(``REPRO_FAULT_SPEC``) — injected crashes land *after* any due save, so a
killed run always leaves its latest durable state behind (exactly the
guarantee a real SIGKILL gets).
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil

import numpy as np

from repro.engine import faultinject
from repro.engine.relation import Relation, lex_order

FORMAT = 1


# ---------------------------------------------------------------------------
# env knobs
# ---------------------------------------------------------------------------
def ckpt_dir() -> str | None:
    """Checkpoint directory (``REPRO_CKPT_DIR``); None disables durability."""
    return os.environ.get("REPRO_CKPT_DIR") or None


def ckpt_every() -> int:
    """Save cadence in completed rounds (``REPRO_CKPT_EVERY``, default 1 —
    every boundary; boundaries are already rare for the compiled executors:
    phase exits, not rounds)."""
    return max(int(os.environ.get("REPRO_CKPT_EVERY", "1")), 1)


def ckpt_keep() -> int:
    """How many newest checkpoints survive GC (``REPRO_CKPT_KEEP``)."""
    return max(int(os.environ.get("REPRO_CKPT_KEEP", "3")), 1)


def kb_fingerprint(kb, mode: str) -> str:
    """Identity of a materialization run for resume matching: the rule set,
    the mode, and the store dtype.  Deliberately EXCLUDES the executor and
    the device count — checkpoints restore across both."""
    h = hashlib.sha256()
    for rule in kb.program.rules:
        h.update(repr(rule).encode())
        h.update(b"\n")
    h.update(mode.encode())
    h.update(str(np.dtype(kb.dict.id_dtype)).encode())
    return h.hexdigest()[:16]


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


# ---------------------------------------------------------------------------
# durable store
# ---------------------------------------------------------------------------
class RecoveryManager:
    """Atomic, checksummed checkpoint directory store.

    ``save`` is temp-then-rename with the manifest written last;
    ``load`` walks tags newest-first and returns the first checkpoint
    whose manifest parses, whose fingerprint matches, and whose payload
    checksums verify — anything else is skipped (and a crashed save's
    ``.tmp`` litter is ignored entirely)."""

    def __init__(self, directory: str, keep: int | None = None):
        self.dir = directory
        self.keep = ckpt_keep() if keep is None else keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, tag: int) -> str:
        return os.path.join(self.dir, f"ckpt_{tag:08d}")

    def tags(self) -> list:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("ckpt_") and os.path.isfile(
                    os.path.join(self.dir, d, "MANIFEST.json")):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def drop(self, tag: int) -> None:
        shutil.rmtree(self._path(tag), ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, tag: int, meta: dict, shards, blobs: dict) -> str:
        """Write one checkpoint: ``shards`` is a list of per-shard
        ``{name: np.ndarray}`` payloads, ``blobs`` maps extra filenames to
        bytes.  Returns the committed directory path."""
        tmp = os.path.join(self.dir, f".tmp_ckpt_{tag:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        checksums = {}
        for i, payload in enumerate(shards):
            fn = f"shard_{i}.npz"
            path = os.path.join(tmp, fn)
            np.savez(path, **{k: np.asarray(v) for k, v in payload.items()})
            checksums[fn] = _sha256(path)
        for fn, data in blobs.items():
            path = os.path.join(tmp, fn)
            with open(path, "wb") as f:
                f.write(data)
            checksums[fn] = _sha256(path)
        manifest = {"format": FORMAT, "tag": tag, "meta": meta,
                    "files": checksums}
        mpath = os.path.join(tmp, "MANIFEST.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        final = self._path(tag)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        try:                       # make the rename itself durable
            dfd = os.open(self.dir, os.O_RDONLY)
            os.fsync(dfd)
            os.close(dfd)
        except OSError:
            pass
        self._gc()
        return final

    def _gc(self) -> None:
        for tag in self.tags()[:-self.keep]:
            self.drop(tag)

    # ------------------------------------------------------------------
    def load(self, fingerprint: str | None = None):
        """Newest valid checkpoint as ``(meta, shards, blobs)``, or None."""
        for tag in reversed(self.tags()):
            got = self._load_one(tag, fingerprint)
            if got is not None:
                return got
        return None

    def _load_one(self, tag: int, fingerprint: str | None):
        d = self._path(tag)
        try:
            with open(os.path.join(d, "MANIFEST.json")) as f:
                manifest = json.load(f)
            if manifest.get("format") != FORMAT:
                return None
            meta = manifest["meta"]
            if fingerprint is not None and \
                    meta.get("fingerprint") != fingerprint:
                return None
            for fn, digest in manifest["files"].items():
                if _sha256(os.path.join(d, fn)) != digest:
                    return None
            shards, blobs = [], {}
            for fn in sorted(manifest["files"],
                             key=lambda n: (not n.startswith("shard_"), n)):
                path = os.path.join(d, fn)
                if fn.startswith("shard_") and fn.endswith(".npz"):
                    with np.load(path) as z:
                        shards.append({k: z[k] for k in z.files})
                else:
                    with open(path, "rb") as f:
                        blobs[fn] = f.read()
            return meta, shards, blobs
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            return None


# ---------------------------------------------------------------------------
# SIGTERM guard (process singleton; chained so outer handlers still run)
# ---------------------------------------------------------------------------
_GUARD = None


def preemption_guard():
    global _GUARD
    if _GUARD is None:
        from repro.train.fault import PreemptionGuard
        _GUARD = PreemptionGuard(chain=True)
    return _GUARD


# ---------------------------------------------------------------------------
# executor-facing wrapper
# ---------------------------------------------------------------------------
class EngineCheckpointer:
    """What the three executors actually talk to.

    * ``maybe_resume(st)`` — restore ``kb`` (dictionary + stores + base)
      from the newest valid checkpoint; returns the live deltas as
      ``{pred: (n, ar) np rows}`` (possibly empty for a finished run), or
      None when there is nothing to resume.  Sets the stats cursor and
      ``st.extra["resumed_rounds"]``.
    * ``boundary(st, state_fn)`` — call at every committed round/phase
      boundary.  Saves when due (cadence / preemption / ``done``), then
      runs the fault hooks, then honors a pending SIGTERM by exiting 143
      (the save above already made the state durable).  ``state_fn`` is
      lazy: full stores are only pulled to the host when a save actually
      happens.

    Disabled (all methods cheap no-ops except the fault hooks) when
    ``REPRO_CKPT_DIR`` is unset or ``enabled=False`` (incremental delta
    calls checkpoint nothing: their lifecycle belongs to the caller)."""

    def __init__(self, kb, mode: str, executor: str,
                 enabled: bool | None = None):
        self.kb = kb
        self.mode = mode
        self.executor = executor
        self.faults = faultinject.get_faults()
        d = ckpt_dir()
        self.enabled = (d is not None if enabled is None
                        else bool(enabled) and d is not None)
        self.mgr = RecoveryManager(d) if self.enabled else None
        self.every = ckpt_every()
        self.fingerprint = kb_fingerprint(kb, mode)
        self.guard = preemption_guard() if self.enabled else None
        self.caps_state = None      # from the checkpoint; executors adopt()
        self.resumed_rounds = 0
        self._last_saved = -1

    # ------------------------------------------------------------------
    def maybe_resume(self, st):
        if not self.enabled:
            return None
        loaded = self.mgr.load(self.fingerprint)
        if loaded is None:
            return None
        meta, shards, blobs = loaded
        kb = self.kb
        kb.dict.load_state(pickle.loads(blobs["dict.pkl"]))
        if "caps.pkl" in blobs:
            self.caps_state = pickle.loads(blobs["caps.pkl"])
        stores, deltas, bases = {}, {}, {}
        for payload in shards:
            for key, arr in payload.items():
                kind, _, pred = key.partition("__")
                bucket = {"store": stores, "delta": deltas,
                          "base": bases}.get(kind)
                if bucket is not None:
                    bucket.setdefault(pred, []).append(arr)
        for pred, parts in stores.items():
            kb.rels[pred] = self._to_relation(pred, parts)
        for pred, parts in bases.items():
            kb.base[pred] = self._to_relation(pred, parts)
        st.rounds = int(meta["rounds"])
        st.triggers = int(meta["triggers"])
        st.derived = int(meta["derived"])
        st.extra["resumed_rounds"] = st.rounds
        st.extra["resumed_from"] = (meta.get("executor"),
                                    int(meta.get("ndev", 1)))
        self.resumed_rounds = st.rounds
        self._last_saved = st.rounds
        out = {}
        for pred, parts in deltas.items():
            rows = self._gather(parts)
            if len(rows):
                out[pred] = rows
        return out

    def _gather(self, parts) -> np.ndarray:
        parts = [np.asarray(p) for p in parts if np.asarray(p).size]
        if not parts:
            return np.zeros((0, 1), self.kb.dict.id_dtype)
        rows = parts[0] if len(parts) == 1 else np.concatenate(parts)
        if len(rows):
            # re-establish the global lex order unconditionally: payloads
            # may be per-shard sorted (cross-shard gather) or, for the
            # unsorted-store two-phase executor, in insertion order
            rows = np.ascontiguousarray(rows[np.lexsort(rows.T[::-1])])
        return rows

    def _to_relation(self, pred, parts) -> Relation:
        rows = self._gather(parts)
        ar = max(self.kb.arities.get(pred, rows.shape[1]), 1)
        if rows.shape[1] != ar:
            rows = rows.reshape(-1, ar)
        return Relation.from_numpy(rows, sorted_by=lex_order(ar),
                                   dtype=self.kb.dict.id_dtype)

    # ------------------------------------------------------------------
    def boundary(self, st, state_fn=None, caps=None, done: bool = False):
        preempt = self.guard.requested if self.guard is not None else False
        if (self.enabled and state_fn is not None
                and st.rounds > self._last_saved
                and (done or preempt
                     or st.rounds - self._last_saved >= self.every)):
            self._save(st, state_fn(), caps, done=done)
        self.faults.on_boundary(st.rounds)
        if preempt:
            raise SystemExit(143)

    def final(self, st, state_fn=None, caps=None):
        """Terminal boundary: persists the converged state (empty deltas,
        ``done`` meta) so resuming a finished run is a no-op."""
        self.boundary(st, state_fn, caps=caps, done=True)

    def _save(self, st, shards, caps, done: bool):
        meta = {"fingerprint": self.fingerprint, "executor": self.executor,
                "mode": self.mode, "rounds": st.rounds,
                "triggers": st.triggers, "derived": st.derived,
                "ndev": len(shards), "done": bool(done)}
        blobs = {"dict.pkl": pickle.dumps(
            self.kb.dict.state_dict(), protocol=pickle.HIGHEST_PROTOCOL)}
        if caps is not None:
            blobs["caps.pkl"] = pickle.dumps(
                caps.state(), protocol=pickle.HIGHEST_PROTOCOL)
        path = self.mgr.save(st.rounds, meta, shards, blobs)
        self._last_saved = st.rounds
        st.extra["checkpoints"] = st.extra.get("checkpoints", 0) + 1
        self.faults.on_checkpoint(path, st.rounds)
