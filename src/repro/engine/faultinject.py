"""Deterministic fault injection for the materialization engine.

``REPRO_FAULT_SPEC`` holds a comma-separated list of fault events; each
event is ``kind[:field=value...]``.  The injector is consulted by the
executors at round/phase boundaries (never mid-program: a compiled round
either fully commits or is discarded, so every injected crash lands on a
consistent host-side state) and by the capacity planner at construction.

Supported events::

    crash:round=K          SIGKILL the process at the first boundary whose
                           completed-round count reaches K (rehearses node
                           loss; nothing is flushed, resume must come from
                           the last durable checkpoint)
    sigterm:round=K        deliver a real SIGTERM to self at round K — the
                           PreemptionGuard path: the driver saves a
                           checkpoint at the next boundary and exits 143
    sleep:round=K:secs=S   straggler: sleep S seconds at every boundary
                           from round K on (default 0.01)
    storm                  forced-overflow storm: the capacity planner
                           starts every delta/bucket/join guess at the
                           floor, so every cold phase pays the full
                           double-and-retry ladder (exercises RetryBudget
                           and multiplies checkpointable boundaries)
    ckpt_corrupt:tag=K:seed=S
                           flip one seeded byte in a payload file of the
                           first checkpoint written with tag >= K
                           (exercises the checksum-validation fallback)

Faults are deterministic: the only randomness is ``random.Random(seed)``
in ``corrupt_file``.  One-shot events (crash / sigterm / ckpt_corrupt)
fire at most once per process.
"""
from __future__ import annotations

import os
import random
import signal
import time


class FaultSpec:
    """Parsed ``REPRO_FAULT_SPEC``; all hooks are no-ops when empty."""

    def __init__(self, text: str = ""):
        self.events: dict = {}
        self._fired: set = set()
        for part in (text or "").split(","):
            part = part.strip()
            if not part:
                continue
            fields = part.split(":")
            kind, kv = fields[0], {}
            for f in fields[1:]:
                k, _, v = f.partition("=")
                kv[k] = v
            self.events[kind] = kv

    @property
    def active(self) -> bool:
        return bool(self.events)

    def _round_of(self, kind: str, default: int = 1) -> int:
        return int(self.events[kind].get("round", default))

    def tiny_caps(self) -> bool:
        """True when the planner should start delta-family guesses at the
        floor (the ``storm`` event)."""
        return "storm" in self.events

    def on_boundary(self, rounds: int) -> None:
        """Called by the executors at each completed round/phase boundary
        (after any due checkpoint save, so an injected crash always leaves
        the latest durable state behind)."""
        ev = self.events.get("sleep")
        if ev is not None and rounds >= int(ev.get("round", 1)):
            time.sleep(float(ev.get("secs", 0.01)))
        if "sigterm" in self.events and "sigterm" not in self._fired \
                and rounds >= self._round_of("sigterm"):
            self._fired.add("sigterm")
            os.kill(os.getpid(), signal.SIGTERM)
        if "crash" in self.events and "crash" not in self._fired \
                and rounds >= self._round_of("crash"):
            self._fired.add("crash")
            os.kill(os.getpid(), signal.SIGKILL)

    def on_checkpoint(self, ckpt_dir: str, tag: int) -> None:
        """Called right after a checkpoint directory is committed."""
        ev = self.events.get("ckpt_corrupt")
        if ev is None or "ckpt_corrupt" in self._fired \
                or tag < int(ev.get("tag", 0)):
            return
        self._fired.add("ckpt_corrupt")
        for name in sorted(os.listdir(ckpt_dir)):
            if name.endswith(".npz") or name.endswith(".pkl"):
                corrupt_file(os.path.join(ckpt_dir, name),
                             seed=int(ev.get("seed", 0)))
                return


def corrupt_file(path: str, seed: int = 0) -> None:
    """Flip one deterministic byte in ``path`` (the fault the checksum
    validation must catch)."""
    size = os.path.getsize(path)
    if size == 0:
        with open(path, "wb") as f:
            f.write(b"\xff")
        return
    rng = random.Random(seed)
    pos = rng.randrange(size)
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0xFF]))


_CACHE: dict = {}


def get_faults() -> FaultSpec:
    """The process fault spec (parsed from ``REPRO_FAULT_SPEC``); cached
    per spec string so one-shot events fire once even though every
    executor entry re-reads the env."""
    text = os.environ.get("REPRO_FAULT_SPEC", "")
    spec = _CACHE.get(text)
    if spec is None:
        spec = _CACHE[text] = FaultSpec(text)
    return spec
