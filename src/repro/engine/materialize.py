"""Vectorized materialization executors over dictionary-encoded relations.

Modes
-----
* ``seminaive``  — the chase baseline (SNE, per-rule redundancy filtering à la
  VLog: derived facts are deduped against the store right after each rule).
* ``tg``         — TG-guided execution (GLog): per-round nodes are (rule,
  delta-position) groups — the engine-level coalescing of Def. 9 combination
  nodes — executed over *parent* instances only, with the Def. 23 antijoin
  pre-restriction and redundancy filtering once per round.
* ``tg_linear``  — reasoning over a precomputed instance-independent TG
  (tglinear/minLinear) for linear programs, with either deferred collective
  cleaning ("w/ cleaning") or none ("w/o cleaning", counts redundant
  derivations like Table 8a).

Trigger counts = total body instantiations (join output rows / filtered
linear-scan rows) — the paper's hardware-independent work metric.

With ``REPRO_FUSED=1``, the ``tg``/``tg_noopt`` modes route through the
fused round executor (``repro.engine.fused``): whole rounds compile to one
XLA program, and linear-tail fixpoints run inside ``lax.while_loop``.
Programs outside the fused fragment (existentials, disconnected bodies)
fall back to the two-phase executor below; results are identical either
way (gated by ``tests/test_differential.py``).

With ``backend="dist"`` (or ``REPRO_DIST=1``), the same rule plans run on
the sharded shard_map executor (``repro.engine.distributed``): facts
hash-partitioned across local devices, exchanges at the join / absorb
boundaries, one host pull per round.  Same fragment, same fallback, same
differential gate.
"""
from __future__ import annotations

import itertools
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.terms import Atom, Program, Rule, Var, is_var
from repro.engine import ops, recovery
from repro.engine.dictionary import Dictionary
from repro.engine.relation import Relation, lex_order


# ---------------------------------------------------------------------------
# KB container
# ---------------------------------------------------------------------------
class EngineKB:
    def __init__(self, program: Program, base_facts, dtype=None):
        """``dtype``: store dtype for this KB's dictionary ids and relation
        columns (default: the process ``REPRO_STORE_DTYPE``)."""
        self.program = program.normalize()
        self.dict = Dictionary(id_dtype=dtype)
        rows = defaultdict(list)
        self.arities = dict(self.program.arities)
        for f in base_facts:
            rows[f.pred].append(f.args)
            self.arities.setdefault(f.pred, f.arity)
        self.rels: Dict[str, Relation] = {}
        # the base (extensional) facts, tracked separately from the derived
        # closure: incremental deletion (DRed) must know which facts exist by
        # fiat — they are never over-deleted away unless explicitly retracted
        self.base: Dict[str, Relation] = {}
        for p, ar in self.arities.items():
            if p in rows:
                rel = Relation.from_numpy(self._encode_block(rows[p], ar))
                # set semantics hold on every path: duplicate base facts are
                # collapsed regardless of REPRO_SORTED_STORE, so fact counts
                # and trigger stats agree across flag settings.  (With the
                # sorted store this doubles as the store invariant: every
                # store relation is lexsorted, so per-round dedup/antijoin
                # skip their sort pass and unions become incremental merges.)
                rel = ops.dedup(rel)
                self.rels[p] = rel
            else:
                self.rels[p] = Relation.empty(max(ar, 1),
                                              dtype=self.dict.id_dtype)
            self.base[p] = self.rels[p]

    def _encode_block(self, fact_args, ar: int) -> np.ndarray:
        """Vectorized encoding of a list of same-arity argument tuples
        (one ``np.unique`` pass via ``Dictionary.encode_columns``); falls
        back to the per-term loop for unorderable mixed terms (Nulls,
        int/str mixes)."""
        n = len(fact_args)
        if n == 0 or ar == 0:
            return np.zeros((n, ar), self.dict.id_dtype)
        try:
            return self.dict.encode_columns(
                np.array(fact_args, dtype=object))
        except TypeError:
            enc = [self.dict.encode_many(args) for args in fact_args]
            return np.asarray(enc, self.dict.id_dtype).reshape(n, ar)

    # -- streamed ingest ----------------------------------------------------
    def ingest_rows(self, pred: str, rows: np.ndarray) -> None:
        """Fold one chunk of base rows for ``pred`` into the store: encode
        the (n, ar) term/ndarray block in one vectorized pass, dedup it,
        antijoin against what the store already holds, and merge the fresh
        rows in with the incremental sorted merge.  Chunked callers never
        hold more than one decoded chunk in memory — the store only ever
        grows by sorted merges.

        Each chunk is ATOMIC: the merged store is staged while the old
        relation stays referenced, and the dictionary's interning growth is
        marked first and rolled back if anything in the chunk fails to
        encode or merge — a malformed chunk raises and leaves both the
        dictionary and the store exactly as they were."""
        rows = np.asarray(rows) if not isinstance(rows, np.ndarray) else rows
        if rows.ndim == 1:
            rows = rows.reshape(-1, 1)
        known = self.arities.get(pred)
        if known is not None and len(rows) and rows.shape[1] != known:
            raise ValueError(
                f"ingest chunk for {pred!r} has arity {rows.shape[1]}, "
                f"store expects {known}")
        token = self.dict.mark()
        try:
            enc = self.dict.encode_columns(rows)
            n, ar = enc.shape
            store = self.rels.get(pred)
            if store is None:
                store = Relation.empty(max(ar, 1),
                                       dtype=self.dict.id_dtype)
            staged = store
            if n:
                rel = ops.dedup(Relation.from_numpy(enc))
                if store.count == 0:
                    staged = rel
                else:
                    fresh = ops.antijoin(rel, store)
                    if fresh.count:
                        staged = ops.merge_union(store, fresh)
        except Exception:
            self.dict.rollback(token)
            raise
        # commit point: dictionary growth and the store swap land together
        self.arities.setdefault(pred, ar)
        self.rels[pred] = staged
        self.base[pred] = staged

    @classmethod
    def from_stream(cls, program: Program, chunks, dtype=None) -> "EngineKB":
        """Build a KB from an iterable of ``(pred, (n, ar) ndarray)`` chunks
        (e.g. the ``*_chunks`` generators in ``repro.data.kb_sources``).
        Equivalent to ``EngineKB(program, atoms)`` over the concatenated
        chunks, but peak memory is one chunk plus the padded store — the
        10^8-fact ingest path."""
        kb = cls(program, (), dtype=dtype)
        for pred, rows in chunks:
            kb.ingest_rows(pred, rows)
        return kb

    @classmethod
    def from_arrays(cls, program: Program, tables, dtype=None) -> "EngineKB":
        """Build a KB from ``{pred: (n, ar) ndarray}`` (or an iterable of
        pairs) of already-materialized term arrays."""
        items = tables.items() if hasattr(tables, "items") else tables
        return cls.from_stream(program, items, dtype=dtype)

    def materialize_delta(self, insertions=(), deletions=(), **kw):
        """Incrementally maintain an already-materialized store: see
        :func:`repro.engine.incremental.materialize_delta`."""
        from repro.engine.incremental import materialize_delta
        return materialize_delta(self, insertions=insertions,
                                 deletions=deletions, **kw)

    def insert_facts(self, facts, **kw):
        return self.materialize_delta(insertions=facts, **kw)

    def delete_facts(self, facts, **kw):
        return self.materialize_delta(deletions=facts, **kw)

    def decode_facts(self):
        out = set()
        for p, rel in self.rels.items():
            ar = self.arities[p]
            for row in rel.np_rows():
                out.add(Atom(p, tuple(self.dict.decode(int(x))
                                      for x in row[:ar])))
        return out

    def num_facts(self):
        return sum(r.count for r in self.rels.values())


# ---------------------------------------------------------------------------
# rule plan execution
# ---------------------------------------------------------------------------
def _atom_filters(atom: Atom, dic: Dictionary):
    """(eq_pairs, const_pairs, var->col) for a single atom scan."""
    eq, consts, var_col = [], [], {}
    for i, t in enumerate(atom.args):
        if is_var(t):
            if t in var_col:
                eq.append((var_col[t], i))
            else:
                var_col[t] = i
        else:
            consts.append((i, dic.encode(t)))
    return tuple(eq), tuple(consts), var_col


def execute_rule(kb: EngineKB, rule: Rule, inputs: List[Relation],
                 prefilter: Optional[Relation] = None,
                 prefilter_mode: str = "anti"):
    """Evaluate the body over per-atom input relations.  Returns
    (head_rel (n, head_arity) possibly with PAD skolem marker cols,
     triggers).

    ``prefilter``: Def. 23 — a relation of already-derived head tuples; if
    some body atom's variables cover the head variables, that atom's input is
    antijoined against it before the join (restricting instantiations).
    ``prefilter_mode="semi"`` inverts the restriction (keep only rows whose
    projected head tuple IS in ``prefilter``) — deletion propagation walks
    rule bodies restricted to heads that exist in the store / over-deleted
    set, the mirror image of the insertion-side redundancy filter."""
    dic = kb.dict
    triggers = 0

    # Def. 23 pre-restriction: if some body atom's columns determine the full
    # head tuple, antijoin that atom's input against the derived head facts.
    pre_j = None
    if prefilter is not None and prefilter.count > 0:
        for j, a in enumerate(rule.body):
            _, _, vc = _atom_filters(a, dic)
            if rule.head.args and all(is_var(t) and t in vc
                                      for t in rule.head.args):
                pre_j = (j, tuple(vc[t] for t in rule.head.args))
                break

    cur = None
    var_col: Dict[Var, int] = {}
    for j, atom in enumerate(rule.body):
        eq, consts, vc = _atom_filters(atom, dic)
        rel = ops.filter_rows(inputs[j], eq, consts)
        if pre_j is not None and pre_j[0] == j:
            rel = (ops.semijoin(rel, prefilter, cols=pre_j[1])
                   if prefilter_mode == "semi"
                   else ops.antijoin(rel, prefilter, cols=pre_j[1]))
        if cur is None:
            cur = rel
            var_col = dict(vc)
            continue
        shared = [v for v in vc if v in var_col]
        if not shared:
            joined, m = ops.cross(cur, rel)
            eq2 = []
        else:
            v0 = shared[0]
            joined, m = ops.sm_join(cur, rel, var_col[v0], vc[v0])
            # post-join equality for remaining shared vars
            eq2 = [(var_col[v], cur.arity + vc[v]) for v in shared[1:]]
        if eq2:
            joined = ops.filter_rows(joined, tuple(eq2), ())
        new_var_col = dict(var_col)
        for v, c in vc.items():
            if v not in new_var_col:
                new_var_col[v] = cur.arity + c
        var_col = new_var_col
        cur = joined
    triggers = cur.count

    # head projection
    exvars = rule.existentials
    if not exvars:
        spec = []
        for t in rule.head.args:
            spec.append(var_col[t] if is_var(t) else None)
        cols = [c for c in spec if c is not None]
        head = ops.project(cur, tuple(c if c is not None else 0
                                      for c in spec))
        if any(c is None for c in spec):
            data = np.array(head.data)   # writable copy (np.asarray views
            # jax buffers read-only)
            for i, (t, c) in enumerate(zip(rule.head.args, spec)):
                if c is None:
                    data[:head.count, i] = dic.encode(t)
            head = Relation.from_numpy(data[:head.count])
        return head, triggers

    # skolem existentials (host-side vectorized)
    frontier = [t for t in rule.head.args if is_var(t) and t in var_col]
    fr_cols = [var_col[t] for t in frontier]
    rows = np.asarray(ops.project(cur, tuple(fr_cols or (0,))).data[:cur.count])
    out = np.zeros((cur.count, len(rule.head.args)), dic.id_dtype)
    fcol = {t: i for i, t in enumerate(frontier)}
    # skolem ids are a function of the frontier tuple, so dictionary lookups
    # only need to run once per DISTINCT frontier row, not once per trigger
    if frontier and cur.count:
        uniq, inv = np.unique(rows[:, :len(frontier)], axis=0,
                              return_inverse=True)
        ftuples = [tuple(int(x) for x in u) for u in uniq]
    else:
        uniq = np.zeros((1 if cur.count else 0, 0), np.int32)
        inv = np.zeros(cur.count, np.intp)
        ftuples = [()] * len(uniq)
    for i, t in enumerate(rule.head.args):
        if is_var(t) and t in fcol:
            out[:, i] = rows[:, fcol[t]]
        elif is_var(t):  # existential
            ids = np.fromiter((dic.skolem((rule.name, t.name, ft))
                               for ft in ftuples), dic.id_dtype,
                              len(ftuples))
            out[:, i] = ids[inv]
        else:
            out[:, i] = dic.encode(t)
    return Relation.from_numpy(out), triggers


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------
@dataclass
class MatStats:
    rounds: int = 0
    triggers: int = 0
    derived: int = 0
    mode: str = ""
    extra: dict = field(default_factory=dict)


def materialize(kb: EngineKB, mode: str = "tg", max_rounds: int = 10_000,
                tg_eg=None, cleaning: bool = True,
                backend: Optional[str] = None) -> MatStats:
    """mode: seminaive (VLog-like, per-rule filtering) | tg_noopt (TG round-
    level filtering) | tg (tg_noopt + Def. 23 prefilter) | tg_linear.

    backend: None (env-driven: ``REPRO_DIST=1`` selects "dist") | "dist"
    (sharded shard_map executor over every local device) | "local".  The
    distributed backend covers the plannable fragment of ``tg``/``tg_noopt``
    (no existentials, connected bodies); anything else falls back to the
    fused / two-phase executors below."""
    if mode == "tg_linear":
        return _materialize_tg_linear(kb, tg_eg, cleaning)
    assert mode in ("seminaive", "tg", "tg_noopt")
    if backend is None and ops.dist_enabled():
        backend = "dist"
    if backend == "dist" and mode in ("tg", "tg_noopt"):
        from repro.engine.distributed import materialize_distributed
        st = materialize_distributed(kb, mode=mode, max_rounds=max_rounds)
        if st is not None:  # None: outside the plannable fragment, fall back
            return st
    if mode in ("tg", "tg_noopt") and ops.fused_enabled():
        from repro.engine.fused import materialize_fused
        st = materialize_fused(kb, mode=mode, max_rounds=max_rounds)
        if st is not None:      # None: outside the fused fragment, fall back
            return st
    per_rule = mode == "seminaive"
    st = MatStats(mode=mode)
    program = kb.program
    deltas: Dict[str, Relation] = {}

    ck = recovery.EngineCheckpointer(kb, mode, "two-phase")
    resume = ck.maybe_resume(st)
    if resume is not None:
        st.extra["resumed"] = True
        for p, rows in resume.items():
            deltas[p] = Relation.from_numpy(
                rows, sorted_by=lex_order(rows.shape[1]),
                dtype=kb.dict.id_dtype)
    else:
        # round 1: extensional rules over B
        derived_round = defaultdict(list)
        for rule in program.extensional_rules():
            inputs = [kb.rels[a.pred] for a in rule.body]
            head, trg = execute_rule(kb, rule, inputs)
            st.triggers += trg
            if per_rule:
                _absorb(kb, st, rule.head.pred, head, deltas)
            elif head.count:
                derived_round[rule.head.pred].append(head)
        st.rounds = 1
        if not per_rule:
            for pred, rels in derived_round.items():
                acc = None
                for r in rels:
                    acc = r if acc is None else ops.union(acc, r,
                                                          dedupe=False)
                _absorb(kb, st, pred, acc, deltas)
        ck.boundary(st, lambda: _host_state(kb, deltas))

    _fixpoint_rounds(kb, st, deltas, mode, max_rounds,
                     per_rule=per_rule, ck=ck)
    return st


def _absorb(kb, st, pred, rel, collector):
    """Dedup + antijoin vs store, merge-append, record delta.

    With the sorted store the delta comes out of ``dedup`` lexsorted, the
    antijoin probes the already-sorted store (no sort pass), and the
    surviving rows — disjoint from the store by construction — are folded
    in with an incremental merge instead of concat + resort."""
    if rel is None or rel.count == 0:
        return
    rel = ops.dedup(rel)
    fresh = ops.antijoin(rel, kb.rels[pred])
    if fresh.count == 0:
        return
    if ops.sorted_store_enabled():
        kb.rels[pred] = ops.merge_union(kb.rels[pred], fresh)
    else:
        kb.rels[pred] = ops.union(kb.rels[pred], fresh, dedupe=False)
    st.derived += fresh.count
    if pred in collector:
        # prior deltas for pred are already in the store, so ``fresh`` is
        # disjoint from them too and the merge path applies
        if ops.sorted_store_enabled():
            collector[pred] = ops.merge_union(collector[pred], fresh)
        else:
            collector[pred] = ops.union(collector[pred], fresh,
                                        dedupe=True)
    else:
        collector[pred] = fresh


def _host_state(kb, deltas):
    """Single-shard checkpoint payload for the two-phase executor: trimmed
    lexsorted host rows for every store / live delta / base relation."""
    def rows_of(rel):
        rows = np.asarray(rel.np_rows())
        if len(rows) and not rel.is_lexsorted:
            rows = rows[np.lexsort(rows.T[::-1])]
        return rows
    payload = {}
    for p, rel in kb.rels.items():
        payload[f"store__{p}"] = rows_of(rel)
    for p, rel in deltas.items():
        if rel.count:
            payload[f"delta__{p}"] = rows_of(rel)
    for p, rel in kb.base.items():
        payload[f"base__{p}"] = rows_of(rel)
    return [payload]


def _fixpoint_rounds(kb, st, deltas, mode, max_rounds,
                     per_rule: bool = False, ck=None):
    """Semi-naive fixpoint rounds of the two-phase executor, continuing
    from ``st.rounds`` with the given live ``deltas`` (pred -> Relation).

    Shared by three callers: ``materialize()``'s two-phase path after its
    round 1, a checkpoint resume (seeded with the restored deltas), and
    the fused / distributed drivers' CapacityError SPILL — their last-good
    stores are already in ``kb.rels``, so finishing here degrades
    throughput but never correctness.  With ``ck`` set, each committed
    round is a checkpoint boundary."""
    program = kb.program
    int_rules = list(program.intensional_rules())
    ext_rules = list(program.extensional_rules())

    while deltas and st.rounds < max_rounds:
        derived_round = defaultdict(list)
        new_deltas: Dict[str, Relation] = {}
        # spilled / incremental seeds may sit on EDB predicates, so
        # extensional rules with a live body atom join the round (for a
        # from-scratch run deltas only ever hold derived predicates and
        # this set is empty — the loop is then the classic SNE round)
        live_ext = [r for r in ext_rules
                    if any(a.pred in deltas for a in r.body)]
        for rule in int_rules + live_ext:
            prefilter = (kb.rels.get(rule.head.pred)
                         if mode == "tg" else None)
            for j, atom in enumerate(rule.body):
                if atom.pred not in deltas:
                    continue
                inputs = []
                for i, a in enumerate(rule.body):
                    inputs.append(deltas[atom.pred] if i == j
                                  else kb.rels[a.pred])
                head, trg = execute_rule(kb, rule, inputs,
                                         prefilter=prefilter)
                st.triggers += trg
                if per_rule:
                    _absorb(kb, st, rule.head.pred, head, new_deltas)
                elif head.count:
                    derived_round[rule.head.pred].append(head)
        st.rounds += 1
        if not per_rule:
            for pred, rels in derived_round.items():
                acc = None
                for r in rels:
                    acc = r if acc is None else ops.union(acc, r,
                                                          dedupe=False)
                _absorb(kb, st, pred, acc, new_deltas)
        deltas = new_deltas
        if ck is not None:
            ck.boundary(st, lambda: _host_state(kb, deltas))
    if ck is not None:
        ck.final(st, lambda: _host_state(kb, deltas))
    return st


def _materialize_tg_linear(kb: EngineKB, eg, cleaning: bool) -> MatStats:
    """Reason over an instance-independent TG (Def. 5) for linear programs."""
    assert eg is not None
    st = MatStats(mode=f"tg_linear[{'w' if cleaning else 'wo'}-cleaning]")
    node_rel: Dict[int, Relation] = {}
    for v in eg.topo_order():
        rule = eg.rule_of[v]
        ps = eg.parents(v)
        src = node_rel[ps[0]] if ps else kb.rels[rule.body[0].pred]
        head, trg = execute_rule(kb, rule, [src])
        st.triggers += trg
        node_rel[v] = head
    st.rounds = eg.graph_depth() + 1
    # union node instances into the store
    by_pred = defaultdict(list)
    for v, rel in node_rel.items():
        by_pred[eg.rule_of[v].head.pred].append(rel)
    for pred, rels in by_pred.items():
        acc = None
        for r in rels:
            acc = r if acc is None else ops.union(acc, r, dedupe=False)
        if acc is None:
            continue
        if cleaning:
            acc = ops.dedup(acc)
            acc = ops.antijoin(acc, kb.rels[pred])
        st.derived += acc.count
        if cleaning and ops.sorted_store_enabled():
            kb.rels[pred] = ops.merge_union(kb.rels[pred], acc)
        else:
            kb.rels[pred] = ops.union(kb.rels[pred], acc, dedupe=not cleaning)
    return st
