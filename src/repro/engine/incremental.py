"""Incremental maintenance of a materialized KB: ``materialize_delta``.

Live serving traffic is updates, not one-shot materialization: re-running
the chase per insert/retract is exactly the redundant computation trigger
graphs exist to avoid.  This module maintains an already-materialized
:class:`EngineKB` under fact insertions and deletions without
re-materializing, with the guarantee (gated by the differential suite) that
the maintained store always equals a from-scratch materialization of the
updated base.

Insertions — semi-naive from a seeded delta
-------------------------------------------
Inserted facts are absorbed into the (sorted) store with an incremental
``merge_union`` and become the FIRST delta of the standard semi-naive loop:
every rule with a body atom over a live delta predicate re-fires against
(delta at one position, full store elsewhere), exactly the engine's
fixpoint rounds but warm.  Shallow cascades (the common case: a few facts,
a couple of rounds) run two-phase at delta-sized buffer capacities; when a
cascade runs deep and ``REPRO_FUSED=1`` with the program in the plannable
fragment, the live deltas are handed to the fused executor
(``materialize_fused(initial_deltas=...)``), so the long tail runs as
compiled whole-round programs and linear fixpoints finish inside one
``lax.while_loop``.  Capacity plans are memoized per
``program_fingerprint`` (``plan._CAP_MEMO``), so repeated delta calls at a
stable KB scale plan right first try: zero overflow retries after the first
call.

Deletions — DRed (delete and re-derive)
---------------------------------------
Deletion propagation follows the classic over-delete / rescue / re-derive
discipline, adapted to the skolem-chase semantics the engine implements
(the chase-variant considerations are surveyed in "The data-exchange chase
under the microscope"; skolem ids are memoized per (rule, exvar, frontier)
so re-derived existential facts keep their null ids):

1. **Over-deletion**: the deleted facts seed a semi-naive loop through the
   rule bodies over the ORIGINAL store, with the Def. 23 pre-restriction
   *inverted* (``execute_rule(..., prefilter_mode="semi")``): candidate
   body rows are kept only when their projected head tuple IS already in
   the store — only existing facts can be over-deleted.  Everything
   reachable from a deleted fact lands in the over-deleted set ``O``.
2. **Commit**: ``store -= O`` per predicate via the sorted set-difference
   ``ops.merge_diff`` (binary-search probes + in-place compaction; the
   store is never re-sorted).
3. **Rescue**: facts in ``O`` that must survive — base facts not
   explicitly retracted (``EngineKB.base`` tracks extensional facts by
   fiat), plus one alternative-derivation pass: every rule re-fires over
   the post-deletion store restricted (inverted prefilter again) to heads
   in ``O``.  Rescued facts re-enter through the insertion path, whose
   semi-naive propagation re-derives any remaining cascade — so one rescue
   pass suffices for completeness.

Backends: insert propagation reuses the fused executor when eligible and
falls back to the two-phase reference loop (existential rules,
disconnected bodies, ``REPRO_FUSED=0``).  The distributed executor does
not take deltas yet (see ROADMAP); ``REPRO_DIST=1`` sessions fall back to
the single-device paths for delta calls.

Semantics of one ``materialize_delta(kb, insertions, deletions)`` call:
deletions are applied first, then insertions (a fact in both sets ends up
present).  The result equals ``materialize(EngineKB(program,
(base - deletions) | insertions))`` up to null renaming.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Optional

import numpy as np

from repro.engine import ops
from repro.engine.materialize import MatStats, execute_rule
from repro.engine.relation import Relation


def _encode_facts(kb, facts) -> Dict[str, Relation]:
    """Encode ground atoms into per-predicate lexsorted deduped relations.
    Unknown predicates are registered with empty store/base relations."""
    rows = defaultdict(list)
    for f in facts:
        if f.pred in kb.arities and f.arity != kb.arities[f.pred]:
            raise ValueError(f"arity mismatch for {f.pred}: got {f.arity}, "
                             f"KB has {kb.arities[f.pred]}")
        rows[f.pred].append(kb.dict.encode_many(f.args))
        if f.pred not in kb.arities:
            kb.arities[f.pred] = f.arity
            kb.rels[f.pred] = Relation.empty(max(f.arity, 1),
                                             dtype=kb.dict.id_dtype)
            kb.base[f.pred] = kb.rels[f.pred]
    out = {}
    for p, rws in rows.items():
        ar = kb.arities[p]
        rel = Relation.from_numpy(
            np.asarray(rws, kb.dict.id_dtype).reshape(len(rws), ar))
        out[p] = ops.dedup(rel)
    return out


def _absorb(kb, pred: str, rel: Optional[Relation]) -> Optional[Relation]:
    """Dedup + antijoin ``rel`` against the store and fold the fresh rows in
    (same contract as the materializer's round absorb).  Returns the fresh
    delta, or None when nothing new."""
    if rel is None or rel.count == 0:
        return None
    rel = ops.dedup(rel)
    fresh = ops.antijoin(rel, kb.rels[pred])
    if fresh.count == 0:
        return None
    if ops.sorted_store_enabled():
        kb.rels[pred] = ops.merge_union(kb.rels[pred], fresh)
    else:
        kb.rels[pred] = ops.union(kb.rels[pred], fresh, dedupe=False)
    return fresh


def _fold(rels):
    acc = None
    for r in rels:
        acc = r if acc is None else ops.union(acc, r, dedupe=False)
    return acc


# ---------------------------------------------------------------------------
# insertion side: semi-naive propagation from a seeded delta
# ---------------------------------------------------------------------------
#
# Small deltas run two-phase on purpose: ops size their buffers to the
# actual delta (pow2 of a handful of rows), while the fused round programs
# are compiled at the memoized FROM-SCRATCH capacities — reusing them for a
# one-fact delta pays full-scratch-round cost per round (measured ~30x
# slower on a 37k-fact TC).  Only when the cascade runs deep (many rounds,
# e.g. extending a chain) does the fused executor's on-device fixpoint win
# over per-round host stepping, so propagation hands off to
# ``materialize_fused(initial_deltas=...)`` after ``_FUSED_HANDOFF`` rounds.
_FUSED_HANDOFF = 3


def _propagate(kb, seeds: Dict[str, Relation], st: MatStats, mode: str,
               max_rounds: int) -> None:
    """Run the semi-naive delta loop from ``seeds`` (already absorbed into
    the store).  Hands deep cascades off to the fused executor."""
    deltas = dict(seeds)
    fused_ok = ops.fused_enabled() and mode in ("tg", "tg_noopt")
    for rounds in range(max_rounds):
        if not deltas:
            break
        if fused_ok and rounds >= _FUSED_HANDOFF:
            from repro.engine.fused import materialize_fused
            from repro.engine.plan import CapacityError
            try:
                fst = materialize_fused(kb, mode=mode,
                                        max_rounds=max_rounds - rounds,
                                        initial_deltas=deltas,
                                        spill=False)
            except CapacityError as e:
                # retry budget exhausted before the handoff made progress:
                # stay on the two-phase loop, whose buffers track the
                # actual delta size instead of doubling whole round plans
                st.extra["spilled"] = str(e)
                fst = None
            if fst is not None:
                st.rounds += fst.rounds
                st.triggers += fst.triggers
                st.derived += fst.derived
                st.extra["propagated"] += fst.derived
                st.extra["fused"] = True
                return
            fused_ok = False    # outside the plannable fragment
        derived_round = defaultdict(list)
        for rule in kb.program.rules:
            prefilter = kb.rels.get(rule.head.pred) if mode == "tg" else None
            for j, atom in enumerate(rule.body):
                if atom.pred not in deltas:
                    continue
                inputs = [deltas[atom.pred] if i == j else kb.rels[a.pred]
                          for i, a in enumerate(rule.body)]
                head, trg = execute_rule(kb, rule, inputs,
                                         prefilter=prefilter)
                st.triggers += trg
                if head.count:
                    derived_round[rule.head.pred].append(head)
        st.rounds += 1
        new_deltas: Dict[str, Relation] = {}
        for pred, rels in derived_round.items():
            fresh = _absorb(kb, pred, _fold(rels))
            if fresh is not None:
                new_deltas[pred] = fresh
                st.derived += fresh.count
                st.extra["propagated"] += fresh.count
        deltas = new_deltas


# ---------------------------------------------------------------------------
# deletion side: DRed over-deletion + rescue
# ---------------------------------------------------------------------------
def _over_delete(kb, present: Dict[str, Relation], st: MatStats,
                 max_rounds: int) -> Dict[str, Relation]:
    """Close ``present`` (deleted facts actually in the store) under
    "derivable using a deleted fact": semi-naive over the ORIGINAL store
    with the Def. 23 prefilter inverted.  Returns the over-deleted set."""
    over = dict(present)
    deltas = dict(present)
    for _ in range(max_rounds):
        if not deltas:
            break
        derived_round = defaultdict(list)
        for rule in kb.program.rules:
            pref = kb.rels.get(rule.head.pred)
            pref = pref if pref is not None and pref.count else None
            for j, atom in enumerate(rule.body):
                if atom.pred not in deltas:
                    continue
                inputs = [deltas[atom.pred] if i == j else kb.rels[a.pred]
                          for i, a in enumerate(rule.body)]
                head, trg = execute_rule(kb, rule, inputs, prefilter=pref,
                                         prefilter_mode="semi")
                st.triggers += trg
                if head.count:
                    derived_round[rule.head.pred].append(head)
        st.rounds += 1
        new_deltas: Dict[str, Relation] = {}
        for pred, rels in derived_round.items():
            acc = ops.dedup(_fold(rels))
            # only facts in the store can be over-deleted, and each only once
            acc = ops.semijoin(acc, kb.rels[pred])
            if pred in over:
                acc = ops.antijoin(acc, over[pred])
            if acc.count == 0:
                continue
            over[pred] = (ops.merge_union(over[pred], acc)
                          if pred in over else acc)
            new_deltas[pred] = acc
        deltas = new_deltas
    return over


def _rescue(kb, over: Dict[str, Relation], st: MatStats) \
        -> Dict[str, Relation]:
    """Facts in ``over`` that must come back: base facts not explicitly
    retracted, plus one alternative-derivation pass over the post-deletion
    store (cascaded re-derivation is completed by the insertion loop the
    rescued facts are fed into)."""
    rescued: Dict[str, Relation] = {}
    for p, rel in over.items():
        base = kb.base.get(p)
        if base is not None and base.count:
            keep = ops.semijoin(rel, base)
            if keep.count:
                rescued[p] = keep
    derived_round = defaultdict(list)
    for rule in kb.program.rules:
        over_h = over.get(rule.head.pred)
        if over_h is None or over_h.count == 0:
            continue
        inputs = [kb.rels[a.pred] for a in rule.body]
        head, trg = execute_rule(kb, rule, inputs, prefilter=over_h,
                                 prefilter_mode="semi")
        st.triggers += trg
        if head.count:
            derived_round[rule.head.pred].append(head)
    for pred, rels in derived_round.items():
        acc = ops.semijoin(ops.dedup(_fold(rels)), over[pred])
        if acc.count == 0:
            continue
        rescued[pred] = (ops.union(rescued[pred], acc, dedupe=True)
                         if pred in rescued else acc)
    return rescued


def _delete(kb, dels: Dict[str, Relation], st: MatStats,
            max_rounds: int) -> Dict[str, Relation]:
    """DRed deletion: over-delete, commit ``store -= O`` via ``merge_diff``,
    rescue.  Returns the rescued facts (to be re-inserted by the caller)."""
    # requested deletions restricted to facts actually present
    present = {}
    for p, rel in dels.items():
        pr = ops.semijoin(rel, kb.rels[p])
        if pr.count:
            present[p] = pr
    # explicit retraction always leaves the base set (base facts are only
    # protected from OVER-deletion, never from the user's own delete)
    for p, rel in dels.items():
        base = kb.base.get(p)
        if base is not None and base.count:
            kb.base[p] = ops.merge_diff(base, rel)
    if not present:
        return {}
    over = _over_delete(kb, present, st, max_rounds)
    st.extra["over_deleted"] += sum(r.count for r in over.values())
    for p, rel in over.items():
        kb.rels[p] = ops.merge_diff(kb.rels[p], rel)
    rescued = _rescue(kb, over, st)
    st.extra["rescued"] += sum(r.count for r in rescued.values())
    return rescued


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------
def materialize_delta(kb, insertions=(), deletions=(), mode: str = "tg",
                      max_rounds: int = 10_000) -> MatStats:
    """Incrementally maintain the materialized ``kb`` under a batch of fact
    ``insertions`` and ``deletions`` (ground :class:`Atom` iterables).

    Deletions apply first (DRed over-deletion / rescue), then insertions
    (semi-naive from the seeded delta; fused when eligible) — a fact in
    both batches ends up present.  The maintained store equals a
    from-scratch materialization of the updated base (differentially
    tested), at a cost that scales with the size of the affected delta, not
    the KB.  ``mode`` controls the Def. 23 pre-restriction on the insertion
    side exactly as in ``materialize`` (``tg`` = prefiltered)."""
    assert mode in ("seminaive", "tg", "tg_noopt")
    st = MatStats(mode=f"delta[{mode}]")
    st.extra.update(delta=True, over_deleted=0, rescued=0, propagated=0)
    dels = _encode_facts(kb, deletions) if deletions else {}
    ins = _encode_facts(kb, insertions) if insertions else {}
    st.extra["deleted"] = sum(r.count for r in dels.values())
    st.extra["inserted"] = sum(r.count for r in ins.values())

    rescued = _delete(kb, dels, st, max_rounds) if dels else {}

    # inserted facts become base facts by fiat
    for p, rel in ins.items():
        base = kb.base.get(p)
        kb.base[p] = (ops.union(base, rel, dedupe=True)
                      if base is not None and base.count else rel)

    # seed the semi-naive loop with whatever is genuinely new to the store:
    # user insertions plus rescued facts
    seeds: Dict[str, Relation] = {}
    for p in sorted(set(ins) | set(rescued)):
        cand = _fold([r for r in (ins.get(p), rescued.get(p))
                      if r is not None])
        fresh = _absorb(kb, p, cand)
        if fresh is not None:
            seeds[p] = fresh
            st.derived += fresh.count
    if seeds:
        _propagate(kb, seeds, st, mode, max_rounds)
    return st
