"""Distributed TG-guided materialization (beyond-paper: the paper lists
distributed KBs as future work).

Facts are hash-partitioned across the ``data`` mesh axis.  Each semi-naive /
TG round:

  1. re-partition the delta by the join key (fixed-capacity bucket exchange
     via ``all_to_all``),
  2. local sort-merge join against the co-partitioned EDB,
  3. re-partition derivations by full-tuple hash (so duplicates land on the
     same shard), local dedup + antijoin against the local store,
  4. global convergence via ``psum`` of per-shard delta counts.

Everything is shape-stable (static per-shard capacities), so the whole
multi-round loop lowers to a single XLA program (``lax.while_loop``) that the
multi-pod dry-run compiles for the production mesh.

The join / dedup / membership / compaction inner loops are the traceable
cores from ``repro.engine.ops`` — the same code the single-device two-phase
wrappers and the fused round executor run — so both execution tiers share
one compiled-round architecture.  Pallas routing is pinned off here: the
kernels are not shard_map-transformable in interpret mode.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.engine.ops import (compact_core, dedup_mask_core, join_count_core,
                              join_gather_core, keysort_core, lexsort_core,
                              member_mask_core, project_core)
from repro.engine.relation import PAD


def _hash32(x):
    """Cheap int32 mix (Wang hash variant, stays in int32)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7feb352d)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846ca68b)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _tuple_hash(rows):
    h = jnp.uint32(0x9e3779b9)
    for c in range(rows.shape[1]):
        h = _hash32(rows[:, c].astype(jnp.uint32) + h)
    return h


def _exchange(rows, target, ndev, axis, bucket_cap):
    """Fixed-capacity bucket exchange: rows (cap, ar) with target shard ids;
    rows routed via all_to_all; returns ((ndev*bucket_cap, ar) local rows,
    dropped_count) — overflowed rows are counted, so the driver can retry
    with bigger buckets."""
    cap, ar = rows.shape
    valid = rows[:, 0] != PAD
    target = jnp.where(valid, target, ndev)          # invalid -> trash bucket
    order = jnp.argsort(target)
    t_sorted = target[order]
    rows_sorted = rows[order]
    pos = jnp.arange(cap) - jnp.searchsorted(t_sorted, t_sorted, side="left")
    slot = jnp.where(t_sorted < ndev, t_sorted * bucket_cap + pos,
                     ndev * bucket_cap)
    overflow = jnp.logical_and(t_sorted < ndev, pos >= bucket_cap)
    slot = jnp.where(overflow, ndev * bucket_cap, slot)
    buckets = jnp.full((ndev * bucket_cap + 1, ar), PAD, jnp.int32)
    buckets = buckets.at[slot].set(jnp.where((t_sorted < ndev)[:, None],
                                             rows_sorted, PAD), mode="drop")
    buckets = buckets[:ndev * bucket_cap].reshape(ndev, bucket_cap, ar)
    recv = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    return recv.reshape(ndev * bucket_cap, ar), jnp.sum(overflow)


@dataclass(frozen=True)
class DistConfig:
    shard_cap: int = 1 << 14         # per-shard store capacity
    delta_cap: int = 1 << 12         # per-shard delta capacity
    bucket_cap: int = 1 << 9         # per-destination exchange bucket
    max_rounds: int = 64
    axis: tuple = ("data",)          # mesh axes facts are partitioned over


def _axis_size(mesh, axis):
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]
    return n


def distributed_tc_step(cfg: DistConfig, ndev: int):
    """Builds the shard_map body for one full TC materialization:
    T(X,Y) <- e(X,Y);   T(X,Z) <- T(X,Y) & e(Y,Z).

    State per shard: store T (shard_cap, 2) [tuple-hash partitioned],
    edges e (shard_cap, 2) [partitioned by col 0 = Y-join side], delta.
    """
    axis = cfg.axis

    def body(e_by_src, t0):
        # t0: initial T = e, tuple-hash partitioned
        e_sorted = keysort_core(e_by_src, 0, pallas=False)

        def round_fn(state):
            t_store, delta, total_trg, rounds, done, dropped = state
            # 1) repartition delta by join col (Y = col 1)
            tgt = (_hash32(delta[:, 1].astype(jnp.uint32))
                   % jnp.uint32(ndev)).astype(jnp.int32)
            d_y, drop1 = _exchange(delta, tgt, ndev, axis, cfg.bucket_cap)
            # 2) local join d_y.Y == e.src, projected to (d.X, e.Z)
            d_sorted = keysort_core(d_y, 1, pallas=False)
            total, per, cum, lo = join_count_core(d_sorted, e_sorted, 1, 0)
            out_cap = cfg.delta_cap * 4
            joined = join_gather_core(d_sorted, e_sorted, per, cum, lo,
                                      total, out_cap)
            new_rows = project_core(joined, (0, 3))
            drop_join = jnp.maximum(total - out_cap, 0)
            # 3) repartition by tuple hash, dedup, antijoin vs store
            tgt2 = (_tuple_hash(new_rows) % jnp.uint32(ndev)).astype(jnp.int32)
            arrived, drop2 = _exchange(new_rows, tgt2, ndev, axis,
                                       cfg.bucket_cap)
            arr_sorted = lexsort_core(arrived, pallas=False)
            uniq = dedup_mask_core(arr_sorted, pallas=False)
            store_sorted = lexsort_core(t_store, pallas=False)
            fresh = jnp.logical_and(uniq, jnp.logical_not(
                member_mask_core(arr_sorted, store_sorted)))
            new_delta = compact_core(arr_sorted, fresh, cfg.delta_cap)
            n_new = jnp.sum(fresh)
            drop_delta = jnp.maximum(n_new - cfg.delta_cap, 0)
            # 4) append to store (out-of-bounds writes dropped)
            n_store = jnp.sum(t_store[:, 0] != PAD)
            drop_store = jnp.maximum(n_store + n_new - cfg.shard_cap, 0)
            pos = jnp.cumsum(fresh) - 1 + n_store
            idx = jnp.where(fresh, pos, cfg.shard_cap)
            t_store = t_store.at[idx].set(arr_sorted, mode="drop")
            total_new = jax.lax.psum(n_new, axis)
            total_trg = total_trg + jax.lax.psum(total, axis)
            dropped = dropped + jax.lax.psum(
                drop1 + drop2 + drop_join + drop_delta + drop_store, axis)
            return (t_store, new_delta, total_trg, rounds + 1,
                    total_new == 0, dropped)

        def cond_fn(state):
            _, _, _, rounds, done, _ = state
            return jnp.logical_and(jnp.logical_not(done),
                                   rounds < cfg.max_rounds)

        state = (t0, t0[:cfg.delta_cap], jnp.zeros((), jnp.int32),
                 jnp.zeros((), jnp.int32), jnp.array(False),
                 jnp.zeros((), jnp.int32))
        t_store, delta, triggers, rounds, done, dropped = jax.lax.while_loop(
            cond_fn, round_fn, state)
        count = jnp.sum(t_store[:, 0] != PAD)
        return t_store, jax.lax.psum(count, axis), triggers, rounds, dropped

    return body


def run_distributed_tc(edges: np.ndarray, mesh, cfg: DistConfig = DistConfig()):
    """edges: (n,2) int32.  Partitions by hash, runs the shard_map loop."""
    ndev = _axis_size(mesh, cfg.axis)
    # host-side initial partitioning
    def whash(x):
        x = (x ^ (x >> 16)) * np.uint32(0x7feb352d)
        x = (x ^ (x >> 15)) * np.uint32(0x846ca68b)
        return x ^ (x >> 16)
    tgt_src = whash(edges[:, 0].astype(np.uint32)) % ndev      # e by src col
    th = np.uint32(0x9e3779b9)
    for c in range(2):
        th = whash(edges[:, c].astype(np.uint32) + th)
    tgt_tuple = th % ndev

    def place(rows, tgt):
        out = np.full((ndev, cfg.shard_cap, 2), np.iinfo(np.int32).max,
                      np.int32)
        fill = np.zeros(ndev, np.int64)
        for r, t in zip(rows, tgt):
            out[t, fill[t]] = r
            fill[t] += 1
        return out.reshape(ndev * cfg.shard_cap, 2)

    # retry loop: silent truncation is never acceptable — if any capacity
    # overflowed, double the buckets/deltas (bounded pow-2 growth, same
    # two-phase discipline as the single-node engine)
    for attempt in range(6):
        e_sharded = place(edges, tgt_src)
        t_sharded = place(edges, tgt_tuple)
        body = distributed_tc_step(cfg, ndev)
        fn = jax.jit(shard_map(
            body, mesh=mesh,
            in_specs=(P(cfg.axis, None), P(cfg.axis, None)),
            out_specs=(P(cfg.axis, None), P(), P(), P(), P())))
        t_store, count, triggers, rounds, dropped = fn(
            jnp.asarray(e_sharded), jnp.asarray(t_sharded))
        if int(dropped) == 0:
            return t_store, int(count), int(triggers), int(rounds)
        cfg = DistConfig(shard_cap=cfg.shard_cap * 2,
                         delta_cap=cfg.delta_cap * 2,
                         bucket_cap=cfg.bucket_cap * 2,
                         max_rounds=cfg.max_rounds, axis=cfg.axis)
    raise RuntimeError("distributed materialization: capacity retries "
                       "exhausted")


def lower_distributed_tc(mesh, cfg: DistConfig = DistConfig()):
    """Dry-run entry: lower+compile the distributed loop on a target mesh."""
    ndev = _axis_size(mesh, cfg.axis)
    body = distributed_tc_step(cfg, ndev)
    fn = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(cfg.axis, None), P(cfg.axis, None)),
        out_specs=(P(cfg.axis, None), P(), P(), P(), P())))
    n = ndev * cfg.shard_cap
    spec = jax.ShapeDtypeStruct((n, 2), jnp.int32)
    return fn.lower(spec, spec)
