"""Distributed materialization: a ``shard_map`` executor over the shared
rule-plan IR (beyond-paper: the paper lists distributed KBs as future work).

This is the third physical executor over ``repro.engine.plan``'s
:class:`RulePlan` IR — the same plans the fused single-device executor
compiles, run over hash-partitioned shards.  It handles *arbitrary* Datalog
programs in the plannable fragment (no existentials, connected bodies), not
just the hand-written transitive closure the first version shipped with.

Data model (:class:`ShardedKB` state, kept as device arrays between
rounds): every predicate's store is partitioned across the mesh ``axis`` by
the full-tuple hash — the canonical home of a fact is the shard its hash
picks, which makes dedup and the antijoin against the store purely local —
and each shard keeps its rows lexsorted (the same ``Relation.sorted_by``
store invariant as the single-device engine, so the shared ops cores skip
their sort passes on store inputs).

Each semi-naive / TG round compiles to ONE ``shard_map`` program (cached by
its static signature) that:

  1. walks every active ``(rule plan, delta position)`` with the shared
     chain walker ``_exec_rule_traced``, passing a ``route`` hook that
     re-partitions rows by join key before each join side (and by projected
     head-tuple hash before the Def. 23 antijoin pre-restriction) via the
     fixed-capacity bucket ``_exchange`` (``all_to_all``),
  2. re-partitions each predicate's derivations by full-tuple hash so
     duplicates land on one shard, then runs the shared ``_absorb_traced``
     (lexsort + dedup + antijoin vs the local store shard + incremental
     sorted merge) locally,
  3. reduces convergence scalars with ``psum``: per-pred fresh-fact totals,
     the trigger total, and the overflow vector.

The host pulls exactly one scalar bundle per round attempt
(``HOST_SYNC_STATS.dist_pulls``) regardless of the shard count — and, once
the remaining program is *linear* (``plan._linear_tail``), the driver stops
stepping rounds from the host at all: the whole fixpoint phase compiles to
ONE ``lax.while_loop``-under-``shard_map`` program
(:func:`_build_dist_fixpoint`) whose convergence check is an on-device
``psum`` folded into the loop carry.  The host then pulls once per
*phase exit* (``HOST_SYNC_STATS.dist_fixpoint_pulls``) — fixpoint reached,
a tail buffer filled (fold, double, resume), or a capacity overflow — instead of
once per round, which is what makes ``dist_pulls`` O(phases) rather than
O(rounds).  Inside the loop, communication overlaps compute: the delta
exchange feeding iteration k+1 is issued at the end of iteration k
(software-pipelined through the carry, dependency-free of the tail merges,
so XLA can run the ``all_to_all`` concurrently with the merge arithmetic),
loop-invariant store-side exchanges are hoisted out of the loop entirely,
and the Def. 23 pre-restriction routing rides the same overlapped window
when it sits on the delta atom.  ``REPRO_DIST_FIXPOINT=0`` forces the
host-stepped per-round path for A/B comparison.

Overflow follows the planner contract from ``repro.engine.plan``: every
planned capacity (store / delta / tail / join / exchange bucket, all per
shard) carries an in-program flag; when any fires the round's (or loop
iteration's) outputs are rolled back to the last good state, the host
doubles exactly the overflowed buckets, recompiles, and retries — a
host-stepped round retry counts in ``HOST_SYNC_STATS.dist_retries``, while
fixpoint-phase capacity retries and tail folds surface as extra
``dist_fixpoint_pulls``, so the two causes stay distinguishable.

Pallas routing is pinned off here: the kernels are not shard_map-
transformable in interpret mode.

Entry points: ``materialize(kb, mode="tg", backend="dist")`` (or
``REPRO_DIST=1``) routes through :func:`materialize_distributed`, falling
back to the fused / two-phase executors for programs outside the fragment;
``run_distributed_tc`` is the back-compat TC wrapper; ``lower_distributed_tc``
lowers one TC round on a target mesh for the multi-pod dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.engine import ops, recovery
from repro.engine.plan import (_absorb_traced, _cached_program, _Caps,
                               _exec_rule_traced, _linear_tail,
                               _select_state, CapacityError,
                               compile_rule_plan, program_fingerprint,
                               RetryBudget)
from repro.engine.relation import Relation, lex_order, pad_of, pad_value
from repro.launch.mesh import axis_size


# ---------------------------------------------------------------------------
# hashing (device + host mirrors must agree: initial placement partitions on
# the host with the same function the exchanges use on device)
# ---------------------------------------------------------------------------
def _hash32(x):
    """Cheap int32 mix (Wang hash variant, stays in int32)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _cols_hash(rows, cols):
    """Combined hash of the given columns of each row (uint32)."""
    h = jnp.uint32(0x9E3779B9)
    for c in cols:
        h = _hash32(rows[:, c].astype(jnp.uint32) + h)
    return h


def _tuple_hash(rows):
    return _cols_hash(rows, range(rows.shape[1]))


def _np_hash32(x):
    x = x.astype(np.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def np_tuple_hash(rows: np.ndarray) -> np.ndarray:
    """Host mirror of ``_tuple_hash`` for the initial placement."""
    h = np.uint32(0x9E3779B9)
    out = np.full(rows.shape[0], h, np.uint32)
    for c in range(rows.shape[1]):
        out = _np_hash32(rows[:, c].astype(np.uint32) + out)
    return out


# ---------------------------------------------------------------------------
# fixed-capacity bucket exchange
# ---------------------------------------------------------------------------
def _route_to_buckets(rows, target, ndev, bucket_cap, sort_cols=None):
    """Pure bucketization half of ``_exchange`` (property-tested on its
    own): scatter rows into per-destination buckets of ``bucket_cap`` rows,
    preserving input order within each bucket (``argsort`` is stable).
    Invalid (PAD) rows are discarded; valid rows beyond a destination's
    capacity are counted.  With ``sort_cols`` (a column sequence) the
    within-bucket order becomes lexicographic by those columns instead of
    input order — one composite (destination, cols...) lexsort, no costlier
    than the plain destination argsort, which hands every receiver
    pre-sorted runs (see ``_merge_runs``).  Returns ((ndev, bucket_cap, ar)
    buckets, overflow_count)."""
    cap, ar = rows.shape
    valid = rows[:, 0] != pad_of(rows)
    target = jnp.where(valid, target, ndev)          # invalid -> trash bucket
    if sort_cols is None:
        order = jnp.argsort(target)
    else:                 # lexsort: LAST key is primary -> target, then cols
        order = jnp.lexsort(tuple(rows[:, c] for c in reversed(sort_cols))
                            + (target,))
    t_sorted = target[order]
    rows_sorted = rows[order]
    pos = jnp.arange(cap) - jnp.searchsorted(t_sorted, t_sorted, side="left")
    slot = jnp.where(t_sorted < ndev, t_sorted * bucket_cap + pos,
                     ndev * bucket_cap)
    overflow = jnp.logical_and(t_sorted < ndev, pos >= bucket_cap)
    slot = jnp.where(overflow, ndev * bucket_cap, slot)
    buckets = jnp.full((ndev * bucket_cap + 1, ar), pad_of(rows), rows.dtype)
    buckets = buckets.at[slot].set(jnp.where((t_sorted < ndev)[:, None],
                                             rows_sorted, pad_of(rows)),
                                   mode="drop")
    return (buckets[:ndev * bucket_cap].reshape(ndev, bucket_cap, ar),
            jnp.sum(overflow))


def _exchange(rows, target, ndev, axis, bucket_cap, sort_cols=None):
    """Fixed-capacity bucket exchange: rows (cap, ar) with target shard ids;
    rows routed via all_to_all; returns ((ndev*bucket_cap, ar) local rows,
    dropped_count) — overflowed rows are counted, so the driver can retry
    with bigger buckets.  ``sort_cols`` orders each bucket by those columns
    before sending (``_route_to_buckets``), so the received block is
    ``ndev`` front-packed sorted runs."""
    buckets, overflow = _route_to_buckets(rows, target, ndev, bucket_cap,
                                          sort_cols=sort_cols)
    recv = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    return recv.reshape(ndev * bucket_cap, rows.shape[1]), overflow


_MERGE_MAX_WAYS = 4      # ndev**2 pairwise rank probes beat a sort up to here


def _merge_runs(blk, ndev, perm):
    """Merge the ``ndev`` per-source sorted runs of an exchanged block into
    one front-packed block lexsorted in ``perm`` column order (``perm`` is
    the full column permutation the sender sorted by, key columns first).

    The sender's composite bucketize sort already ordered every bucket, so
    the receiver only has to merge: a rank-based k-way merge — each row's
    output slot is its index within its run plus one ``searchsorted`` count
    against every other run (ties broken by source-run index, so slots are
    unique), landed with a single scatter.  That is ndev*(ndev-1) binary
    searches over packed keys instead of an O(n log n) re-sort of the whole
    block; at ndev=1 the block is already fully sorted and nothing runs at
    all.  Past ``_MERGE_MAX_WAYS`` runs (or rows too wide to pack) the
    quadratic probe count loses to XLA's sort, so it falls back to one full
    lexsort — same contract, no pre-sorted-run benefit."""
    n, ar = blk.shape
    identity = tuple(perm) == tuple(range(ar))
    if ndev == 1:
        return blk
    cap = n // ndev
    rot = blk if identity else blk[:, list(perm)]
    if ndev > _MERGE_MAX_WAYS or ar > 2 or (
            ar == 2 and not ops._pack_ok(blk.dtype)):
        out = ops.lexsort_core(rot, pallas=False)
    else:
        runs = [rot[i * cap:(i + 1) * cap] for i in range(ndev)]
        valids = [blk[i * cap:(i + 1) * cap, 0] != pad_of(blk)
                  for i in range(ndev)]
        iota = jnp.arange(cap, dtype=jnp.int32)
        with jax.experimental.enable_x64():
            keys = ([r[:, 0] for r in runs] if ar == 1
                    else [ops.pack_rows2(r) for r in runs])
            ranks = []
            for i in range(ndev):
                rank = iota
                for j in range(ndev):
                    if j == i:
                        continue
                    # right for earlier runs / left for later ones: equal
                    # rows order by source run, making every slot unique
                    rank = rank + jnp.searchsorted(
                        keys[j], keys[i],
                        side="right" if j < i else "left").astype(jnp.int32)
                ranks.append(rank)
        out = jnp.full((n + 1, ar), pad_of(blk), blk.dtype)
        for i, r in enumerate(runs):
            pos = jnp.where(valids[i], ranks[i], n)    # PAD rows -> trash
            out = out.at[pos].set(jnp.where(valids[i][:, None], r,
                                            pad_of(blk)),
                                  mode="drop")
        out = out[:n]
    if identity:
        return out
    inv = [0] * ar
    for i, c in enumerate(perm):
        inv[c] = i
    return out[:, inv]


@dataclass(frozen=True)
class DistConfig:
    """Fixed capacities for the dry-run / back-compat entries (the general
    executor plans its own per-shard capacities via ``plan._Caps``)."""
    shard_cap: int = 1 << 14         # per-shard store capacity
    delta_cap: int = 1 << 12         # per-shard delta capacity
    bucket_cap: int = 1 << 9         # per-destination exchange bucket
    max_rounds: int = 64
    axis: tuple = ("data",)          # mesh axes facts are partitioned over


# ---------------------------------------------------------------------------
# overflow-label enumeration (must mirror the flag order the traced round
# emits: _exec_rule_traced appends pre / left / right exchange flags then
# the join-capacity flag, per join step)
# ---------------------------------------------------------------------------
def _rule_ovf_labels(plan, use_pre):
    labels = []
    for j in range(len(plan.atoms)):
        if use_pre and plan.pre is not None and plan.pre[0] == j:
            labels.append(("bucket", (plan.key, "pre", j)))
        if j >= 1:
            labels.append(("bucket", (plan.key, "jl", j)))
            labels.append(("bucket", (plan.key, "jr", j)))
            labels.append(("join", (plan.key, j - 1)))
    return labels


def _round_ovf_labels(active, use_prefilter, derived):
    labels = []
    for plan, _ in active:
        labels += _rule_ovf_labels(plan, use_prefilter)
    for pred in derived:
        labels += [("bucket", ("absorb", pred)),
                   ("delta", pred), ("store", pred)]
    return labels


def _bucket_keys(labels):
    return tuple(name for kind, name in labels if kind == "bucket")


# ---------------------------------------------------------------------------
# compiled sharded round program
# ---------------------------------------------------------------------------
def _dist_signature(mesh, axis, ndev, preds, caps, active, delta_in,
                    use_prefilter):
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    labels = _round_ovf_labels(active, use_prefilter, derived)
    return ("dist_round", mesh, axis, ndev, preds,
            tuple(caps.store[p] for p in preds),
            tuple((plan.key, jd, tuple(caps.join_cap(plan, i)
                                       for i in range(len(plan.joins))))
                  for plan, jd in active),
            tuple((p, caps.delta_cap(p)) for p in delta_in),
            tuple((p, caps.delta_cap(p)) for p in derived),
            tuple((k, caps.bucket_cap(k)) for k in _bucket_keys(labels)),
            use_prefilter)


def _build_dist_round(mesh, axis, ndev, preds, caps, active, delta_in,
                      use_prefilter):
    """One sharded materialization round as a single jitted shard_map
    program.

    Per-shard inputs: store blocks (tuple-hash partitioned, lexsorted, at
    planner capacities) + per-shard counts, plus the live delta blocks.
    Outputs: new stores / counts / deltas (per shard), the psum'd per-pred
    fresh totals, the round's global trigger total, and the psum'd overflow
    vector.  ``ovf_labels`` names each overflow slot so the driver can
    double exactly the right capacity."""
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    ovf_labels = _round_ovf_labels(active, use_prefilter, derived)
    join_caps = {id(plan): tuple(caps.join_cap(plan, i)
                                 for i in range(len(plan.joins)))
                 for plan, _ in active}
    delta_caps = {p: caps.delta_cap(p) for p in derived}
    bucket_caps = {k: caps.bucket_cap(k) for k in _bucket_keys(ovf_labels)}

    def body(store_datas, store_counts, delta_datas):
        stores = dict(zip(preds, store_datas))
        counts = {p: c[0] for p, c in zip(preds, store_counts)}
        deltas = dict(zip(delta_in, delta_datas))
        triggers = jnp.zeros((), jnp.int32)
        ovfs = []
        heads = {}
        for plan, jd in active:
            def route(rows, cols, tag, _pk=plan.key):
                cap = bucket_caps[(_pk, *tag)]
                tgt = (_cols_hash(rows, cols)
                       % jnp.uint32(ndev)).astype(jnp.int32)
                out, dropped = _exchange(rows, tgt, ndev, axis, cap)
                return out, [dropped > 0], None
            inputs = [deltas[bp] if j == jd else stores[bp]
                      for j, bp in enumerate(plan.body_preds)]
            pre_data = stores[plan.head_pred] if use_prefilter else None
            head, trg, flags = _exec_rule_traced(
                plan, inputs, pre_data, join_caps[id(plan)], False,
                route=route)
            triggers += trg
            ovfs += flags
            heads.setdefault(plan.head_pred, []).append(head)
        out_deltas, out_dcounts, fresh_tot = [], [], []
        for pred in derived:
            hs = heads[pred]
            cat = hs[0] if len(hs) == 1 else jnp.concatenate(hs, axis=0)
            # canonical-home repartition: duplicates of a tuple (across
            # rules AND shards) all land on the shard its hash picks, so
            # dedup + the antijoin against the store are local
            tgt = (_tuple_hash(cat) % jnp.uint32(ndev)).astype(jnp.int32)
            routed, dropped = _exchange(cat, tgt, ndev, axis,
                                        bucket_caps[("absorb", pred)])
            ovfs.append(dropped > 0)
            ns, nc, delta, nf, (od, os_) = _absorb_traced(
                [routed],
                lambda rows, p=pred: jnp.logical_not(
                    ops.member_mask_core(rows, stores[p])),
                stores[pred], counts[pred], delta_caps[pred], False)
            stores[pred] = ns
            counts[pred] = nc
            out_deltas.append(delta)
            out_dcounts.append(nf)
            fresh_tot.append(jax.lax.psum(nf, axis))
            ovfs += [od, os_]
        ovf_vec = (jnp.stack(ovfs).astype(jnp.int32) if ovfs
                   else jnp.zeros((0,), jnp.int32))
        return (tuple(stores[p] for p in preds),
                tuple(counts[p].reshape(1) for p in preds),
                tuple(out_deltas),
                tuple(nf.reshape(1) for nf in out_dcounts),
                tuple(fresh_tot),
                jax.lax.psum(triggers, axis),
                jax.lax.psum(ovf_vec, axis))

    in_specs = (tuple(P(axis, None) for _ in preds),
                tuple(P(axis) for _ in preds),
                tuple(P(axis, None) for _ in delta_in))
    out_specs = (tuple(P(axis, None) for _ in preds),
                 tuple(P(axis) for _ in preds),
                 tuple(P(axis, None) for _ in derived),
                 tuple(P(axis) for _ in derived),
                 tuple(P() for _ in derived),
                 P(), P())
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs))
    return fn, ovf_labels, derived


# ---------------------------------------------------------------------------
# compiled linear-tail fixpoint program (lax.while_loop under shard_map)
# ---------------------------------------------------------------------------
def _site_route_tag(plan, jd, use_pre):
    """The exchange tag through which one linear-fixpoint site's DELTA
    first flows — the exchange that gets software-pipelined through the
    loop carry — or None when the site routes nothing delta-side
    (single-atom rule without a usable pre-restriction: its heads only
    move in the absorb exchange)."""
    if use_pre and plan.pre is not None and plan.pre[0] == jd:
        return ("pre", jd)
    if len(plan.atoms) == 1:
        return None
    return ("jl", 1) if jd == 0 else ("jr", jd)


def _site_tags(plan, jd, use_pre):
    """Exchange tags of one linear-fixpoint site (plan with the delta at
    body position ``jd``), in the exact order ``_exec_rule_traced``
    reaches them.  Returns ``(carried_tag, [(tag, kind, key_cols)])``
    where kind is:

    * ``'carried'`` — the first delta-side exchange: its routed block is
      produced at the END of the previous loop iteration (right after the
      fresh delta materializes, with no dependency on the tail merges, so
      the ``all_to_all`` overlaps them) and rides the loop carry,
    * ``'static'`` — routes a loop-invariant store input: hoisted out of
      the loop and exchanged once per fixpoint attempt,
    * ``'live'`` — routes delta-derived rows mid-chain: stays in-loop.
    """
    pre_j = plan.pre[0] if (use_pre and plan.pre is not None) else None
    carried = _site_route_tag(plan, jd, use_pre)
    tags = []
    for j in range(len(plan.atoms)):
        if pre_j == j:
            kind = "carried" if ("pre", j) == carried else "static"
            tags.append((("pre", j), kind, plan.pre[1]))
        if j >= 1:
            lk, rk, _ = plan.joins[j - 1]
            if ("jl", j) == carried:
                kind = "carried"
            elif j == 1 and jd >= 1 and pre_j != 0:
                kind = "static"        # left side of join 1 is a store
            else:
                kind = "live"
            tags.append((("jl", j), kind, (lk,)))
            if ("jr", j) == carried:
                kind = "carried"
            elif j != jd and pre_j != j:
                kind = "static"        # right side is an unfiltered store
            else:
                kind = "live"
            tags.append((("jr", j), kind, (rk,)))
    return carried, tags


def _fix_ovf_labels(active, use_pre, derived):
    """Overflow labels of the fixpoint program, partitioned into its three
    emission groups: *body* (in-loop flags, in traced emission order: live
    exchanges + join caps per site, then absorb-bucket / delta / tail per
    derived pred), *production* (the carried delta-side exchanges, one per
    site that has one, in site order — emitted in-loop after the absorbs),
    and *static* (the hoisted store-side exchanges, emitted once before
    the loop).  The program's overflow vector is body ++ production ++
    static."""
    body, production, static = [], [], []
    for plan, jd in active:
        _, tags = _site_tags(plan, jd, use_pre)
        for tag, kind, _cols in tags:
            label = ("bucket", (plan.key, *tag))
            {"live": body, "carried": production,
             "static": static}[kind].append(label)
            if tag[0] == "jr":
                body.append(("join", (plan.key, tag[1] - 1)))
    for pred in derived:
        body += [("bucket", ("absorb", pred)), ("delta", pred),
                 ("tail", pred)]
    return body, production, static


def _dist_fix_signature(mesh, axis, ndev, s_preds, o_preds, caps, active,
                        use_prefilter, max_rounds):
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    body, prod, static = _fix_ovf_labels(active, use_prefilter, derived)
    bkeys = tuple(name for kind, name in body + prod + static
                  if kind == "bucket")
    return ("dist_fix", mesh, axis, ndev, s_preds, o_preds,
            tuple(caps.store[p] for p in s_preds + o_preds),
            tuple(caps.delta_cap(p) for p in s_preds),
            tuple(caps.tail_cap(p) for p in s_preds),
            tuple((plan.key, jd, tuple(caps.join_cap(plan, i)
                                       for i in range(len(plan.joins))))
                  for plan, jd in active),
            tuple((k, caps.bucket_cap(k)) for k in bkeys),
            use_prefilter, max_rounds)


def _build_dist_fixpoint(mesh, axis, ndev, s_preds, o_preds, caps, active,
                         use_prefilter, max_rounds):
    """The remaining (linear) fixpoint as ONE sharded program: a
    ``lax.while_loop`` whose body is a whole distributed round, with the
    convergence check folded into the carry as on-device ``psum``s — zero
    host pulls until fixpoint, overflow, or ``max_rounds``.

    Cross-shard termination uniformity: everything the loop condition
    reads (live count, round counter, overflow vector) is psum'd in the
    body, so every shard takes the same branch each iteration (a
    collective in the condition itself would be illegal).

    The round body mirrors the fused fixpoint (phase-entry stores as loop
    constants, per-pred sorted tail buffers, probe store | tail, last-good
    rollback on overflow via ``_select_state``) with the distributed
    exchanges layered on per ``_site_tags``: static store-side routes are
    hoisted above the loop, the delta-side route is software-pipelined —
    iteration k closes by sort-bucketizing + exchanging + run-merging the
    delta it just produced, a computation independent of its tail merges, and
    the routed block enters iteration k+1 through the carry (the
    compute/comm-overlap window; the Def. 23 pre-restriction's
    projected-head-hash routing rides it whenever the pre-restriction
    sits on the delta atom).

    Exits return per-shard tails + counts, per-shard deltas + counts, and
    the psum'd rounds / triggers / derived / overflow scalars; the host
    folds tails into the store shards, doubles exactly the overflowed
    capacities, and resumes mid-fixpoint."""
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    body_labels, prod_labels, static_labels = _fix_ovf_labels(
        active, use_prefilter, derived)
    ovf_labels = body_labels + prod_labels + static_labels
    n_body, n_static = len(body_labels), len(static_labels)
    sites = []
    carried_slot = {}                  # site index -> carry tuple slot
    site_cols = {}                     # site index -> carried key cols
    site_skey = {}                     # site index -> static sort key
    for plan, jd in active:
        carried, tags = _site_tags(plan, jd, use_prefilter)
        si = len(sites)
        if carried is not None:
            carried_slot[si] = len(carried_slot)
            cols = next(c for t, _k, c in tags if t == carried)
            site_cols[si] = cols
            # join-side blocks are pre-sorted by the join key at
            # production time (inside the overlap window), so the in-loop
            # chain skips its keysort; pre-restriction blocks are probed,
            # not joined, and need no order
            site_skey[si] = cols[0] if carried[0] != "pre" else None
        sites.append((plan, jd, carried, tags))
    join_caps = {id(plan): tuple(caps.join_cap(plan, i)
                                 for i in range(len(plan.joins)))
                 for plan, _ in active}
    delta_caps = {p: caps.delta_cap(p) for p in s_preds}
    tail_caps = {p: caps.tail_cap(p) for p in s_preds}
    bucket_caps = {name: caps.bucket_cap(name)
                   for kind, name in ovf_labels if kind == "bucket"}

    def exch(rows, cols, key, sort=False):
        tgt = (_cols_hash(rows, cols) % jnp.uint32(ndev)).astype(jnp.int32)
        if not sort:
            out, dropped = _exchange(rows, tgt, ndev, axis, bucket_caps[key])
            return out, dropped > 0
        # sorted exchange: the sender lexsorts each bucket by (cols, rest)
        # inside the composite bucketize sort, the receiver tree-merges the
        # ndev runs — log2(ndev) linear passes replace the post-exchange
        # O(n log n) keysort, and the merged block satisfies the join's
        # skey contract (sorted by cols[0])
        perm = tuple(cols) + tuple(c for c in range(rows.shape[1])
                                   if c not in cols)
        out, dropped = _exchange(rows, tgt, ndev, axis, bucket_caps[key],
                                 sort_cols=perm)
        return _merge_runs(out, ndev, perm), dropped > 0

    def filt(plan, j, data):
        """Atom-j filters on a raw block (production-side routing must see
        the same rows the in-loop chain would route)."""
        eq, consts = plan.atoms[j]
        if eq or consts:
            mask = ops.filter_mask_core(data, eq, consts)
            data = ops.compact_core(data, mask, data.shape[0])
        return data

    def fn(s_datas, d_datas, o_datas, rounds0):
        base = dict(zip(s_preds, s_datas))
        others = dict(zip(o_preds, o_datas))
        deltas0 = dict(zip(s_preds, d_datas))

        def not_seen(rows, pred, tails, cols=None):
            """keep-mask: rows whose (projected) tuple is in neither the
            phase-entry store shard nor the tail shard of ``pred`` —
            callers route rows by the projected tuple's hash first, so
            the canonical-home shard answers membership locally."""
            sel = rows if cols is None else ops.project_core(rows, cols)
            seen = jnp.logical_or(
                ops.member_mask_core(sel, base[pred]),
                ops.member_mask_core(sel, tails[pred]))
            valid = rows[:, 0] != pad_of(rows)
            return jnp.logical_and(valid, jnp.logical_not(seen))

        # hoisted loop-invariant store-side exchanges: routed (and
        # key-sorted) once per fixpoint attempt, loop constants thereafter
        static_routed = {}
        static_flags = []
        for plan, jd, carried, tags in sites:
            for tag, kind, cols in tags:
                if kind != "static":
                    continue
                src_j = 0 if tag[0] == "jl" else tag[1]
                blk, flag = exch(filt(plan, src_j,
                                      others[plan.body_preds[src_j]]),
                                 cols, (plan.key, *tag),
                                 sort=tag[0] != "pre")
                skey = cols[0] if tag[0] != "pre" else None
                static_routed[(id(plan), tag)] = (blk, skey)
                static_flags.append(flag)

        def produce_carried(si, plan, jd, carried, fresh_delta):
            """The overlapped production of one site's next-iteration
            input: filter + sorted-exchange the fresh delta (pre-restriction
            blocks are probed, not joined, so they skip the sort)."""
            return exch(filt(plan, jd, fresh_delta), site_cols[si],
                        (plan.key, *carried), sort=site_skey[si] is not None)

        carried0, prod_flags = [], []
        for si, (plan, jd, carried, tags) in enumerate(sites):
            if carried is None:
                continue
            blk, flag = produce_carried(si, plan, jd, carried,
                                        deltas0[plan.body_preds[jd]])
            carried0.append(blk)
            prod_flags.append(flag)

        init_flags = prod_flags + static_flags
        ovf0 = jnp.concatenate([
            jnp.zeros((n_body,), jnp.int32),
            (jax.lax.psum(jnp.stack(init_flags).astype(jnp.int32), axis)
             if init_flags else jnp.zeros((0,), jnp.int32))])
        d_counts0 = tuple(jnp.sum(deltas0[p][:, 0] != pad_of(deltas0[p])
                                  ).astype(jnp.int32)
                          for p in s_preds)
        live0 = jax.lax.psum(sum(d_counts0), axis)

        def body(state):
            (w_datas, w_counts, d_datas, d_counts, carried_blks, rounds,
             trg, drv, live, _ovf) = state
            tails = dict(zip(s_preds, w_datas))
            wcnt = dict(zip(s_preds, w_counts))
            deltas = dict(zip(s_preds, d_datas))
            triggers = jnp.zeros((), jnp.int32)
            ovfs = []
            heads = {}
            for si, (plan, jd, carried, tags) in enumerate(sites):
                def route(rows, cols, tag, _plan=plan, _carried=carried,
                          _si=si):
                    if tag == _carried:
                        return (carried_blks[carried_slot[_si]], [],
                                site_skey[_si])
                    hit = static_routed.get((id(_plan), tag))
                    if hit is not None:
                        return hit[0], [], hit[1]
                    # live tags are always join sides (_site_tags never
                    # marks a pre tag live), so the sorted exchange lets
                    # the chain skip its keysort too
                    out, flag = exch(rows, cols, (_plan.key, *tag),
                                     sort=True)
                    return out, [flag], cols[0]

                inputs = [deltas[bp] if j == jd else others[bp]
                          for j, bp in enumerate(plan.body_preds)]
                pf = ((lambda rows, cols, p=plan.head_pred:
                       not_seen(rows, p, tails, cols))
                      if use_prefilter and plan.pre is not None else None)
                head, t, flags = _exec_rule_traced(
                    plan, inputs, None, join_caps[id(plan)], False,
                    prefilter=pf, route=route)
                triggers += t
                ovfs += flags
                heads.setdefault(plan.head_pred, []).append(head)
            new_w, new_wc, new_d, new_dc = {}, {}, {}, {}
            for pred in s_preds:
                if pred in heads:
                    hs = heads[pred]
                    cat = (hs[0] if len(hs) == 1
                           else jnp.concatenate(hs, axis=0))
                    tgt = (_tuple_hash(cat)
                           % jnp.uint32(ndev)).astype(jnp.int32)
                    # full-lex sorted exchange: the absorb's own lexsort
                    # collapses to the run merge (presorted=True below)
                    lex = tuple(range(cat.shape[1]))
                    routed, dropped = _exchange(
                        cat, tgt, ndev, axis,
                        bucket_caps[("absorb", pred)], sort_cols=lex)
                    routed = _merge_runs(routed, ndev, lex)
                    ovfs.append(dropped > 0)
                    nw, nc, delta, nf, (od, ow) = _absorb_traced(
                        [routed],
                        lambda rows, p=pred: not_seen(rows, p, tails),
                        tails[pred], wcnt[pred], delta_caps[pred], False,
                        presorted=True)
                    new_w[pred], new_wc[pred] = nw, nc
                    new_d[pred], new_dc[pred] = delta, nf
                    ovfs += [od, ow]
                else:           # in S but not derived by any site: drains
                    new_w[pred] = tails[pred]
                    new_wc[pred] = wcnt[pred]
                    new_d[pred] = jnp.full_like(deltas[pred],
                                                pad_of(deltas[pred]))
                    new_dc[pred] = jnp.zeros((), jnp.int32)
            # overlapped production for iteration k+1: depends only on the
            # fresh deltas, NOT on the tail merges above, so the exchange
            # runs concurrently with them and the routed block enters the
            # next iteration through the carry
            new_carried = []
            for si, (plan, jd, carried, tags) in enumerate(sites):
                if carried is None:
                    continue
                blk, flag = produce_carried(si, plan, jd, carried,
                                            new_d[plan.body_preds[jd]])
                new_carried.append(blk)
                ovfs.append(flag)
            ovf_vec = jnp.concatenate([
                (jax.lax.psum(jnp.stack(ovfs).astype(jnp.int32), axis)
                 if ovfs else jnp.zeros((0,), jnp.int32)),
                jnp.zeros((n_static,), jnp.int32)])
            fresh_tot = jax.lax.psum(sum(new_dc[p] for p in s_preds), axis)
            bad = jnp.any(ovf_vec > 0)

            def keep(old, new):
                return _select_state(bad, old, new)

            return (keep(w_datas, tuple(new_w[p] for p in s_preds)),
                    keep(w_counts, tuple(new_wc[p] for p in s_preds)),
                    keep(d_datas, tuple(new_d[p] for p in s_preds)),
                    keep(d_counts, tuple(new_dc[p] for p in s_preds)),
                    keep(carried_blks, tuple(new_carried)),
                    rounds + jnp.where(bad, 0, 1),
                    trg + jnp.where(bad, 0, jax.lax.psum(triggers, axis)),
                    drv + jnp.where(bad, 0, fresh_tot),
                    jnp.where(bad, live, fresh_tot),
                    ovf_vec)

        def cond(state):
            rounds, live, ovf_vec = state[5], state[8], state[9]
            ok = jnp.logical_not(jnp.any(ovf_vec > 0))
            return jnp.logical_and(jnp.logical_and(live > 0, ok),
                                   rounds < max_rounds)

        state = (
            tuple(jnp.full((tail_caps[p], base[p].shape[1]),
                           pad_of(base[p]), base[p].dtype)
                  for p in s_preds),
            tuple(jnp.zeros((), jnp.int32) for _ in s_preds),
            tuple(deltas0[p] for p in s_preds),
            d_counts0,
            tuple(carried0),
            rounds0, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            live0, ovf0)
        (w_datas, w_counts, d_datas, d_counts, _c, rounds, trg, drv,
         _live, ovf_vec) = jax.lax.while_loop(cond, body, state)
        return (w_datas, tuple(c.reshape(1) for c in w_counts),
                d_datas, tuple(c.reshape(1) for c in d_counts),
                rounds, trg, drv, ovf_vec)

    in_specs = (tuple(P(axis, None) for _ in s_preds),
                tuple(P(axis, None) for _ in s_preds),
                tuple(P(axis, None) for _ in o_preds),
                P())
    out_specs = (tuple(P(axis, None) for _ in s_preds),
                 tuple(P(axis) for _ in s_preds),
                 tuple(P(axis, None) for _ in s_preds),
                 tuple(P(axis) for _ in s_preds),
                 P(), P(), P(), P())
    return (jax.jit(shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)),
            ovf_labels)


# ---------------------------------------------------------------------------
# sharded store (host-side bookkeeping around the device arrays)
# ---------------------------------------------------------------------------
class ShardedKB:
    """Hash-partitioned store: per predicate, a global (ndev * store_cap,
    ar) device array partitioned over the mesh axis (shard = tuple-hash %
    ndev; each shard's valid rows lexsorted) plus per-shard fill counts on
    the host.  ``fit`` re-pads every shard when the planner doubles a store
    capacity (retry path only — steady-state rounds reuse the arrays the
    previous round produced)."""

    def __init__(self, kb, preds, ndev):
        self.ndev = ndev
        self.arity = {p: kb.rels[p].arity for p in preds}
        self.dtype = {p: np.dtype(kb.rels[p].dtype) for p in preds}
        self.data = {}               # pred -> device/np (ndev*cap, ar)
        self.counts = {}             # pred -> np (ndev,) int32
        self.per_shard_max = {}
        for p in preds:
            rows = np.asarray(kb.rels[p].np_rows())
            if rows.size:
                rows = np.unique(rows, axis=0)   # set semantics on entry
            tgt = (np_tuple_hash(rows) % np.uint32(ndev)).astype(np.int64) \
                if len(rows) else np.zeros(0, np.int64)
            parts = []
            for d in range(ndev):
                part = rows[tgt == d]
                if len(part):
                    part = part[np.lexsort(part.T[::-1])]
                parts.append(part)
            self.counts[p] = np.array([len(pt) for pt in parts], np.int32)
            self.per_shard_max[p] = int(self.counts[p].max(initial=0))
            self.data[p] = parts     # packed once planner caps exist

    def pack(self, caps):
        """Materialize the per-shard blocks at the planner's store caps."""
        for p, parts in self.data.items():
            cap = caps.store[p]
            out = np.full((self.ndev, cap, self.arity[p]),
                          pad_value(self.dtype[p]), self.dtype[p])
            for d, part in enumerate(parts):
                out[d, :len(part)] = part
            self.data[p] = out.reshape(self.ndev * cap, self.arity[p])

    def fit(self, pred, cap):
        """Current store block re-padded per shard to ``cap`` rows."""
        data = self.data[pred]
        cur = data.shape[0] // self.ndev
        if cur == cap:
            return data
        return refit_shards(data, self.ndev, cap)

    def to_relations(self, kb):
        """Fold the shards back into lexsorted single-device Relations."""
        for p in self.data:
            ar = self.arity[p]
            blocks = np.asarray(self.data[p]).reshape(self.ndev, -1, ar)
            parts = [blocks[d, :int(self.counts[p][d])]
                     for d in range(self.ndev)]
            rows = (np.concatenate(parts) if parts
                    else np.zeros((0, ar), self.dtype[p]))
            if len(rows):
                rows = rows[np.lexsort(rows.T[::-1])]
            kb.rels[p] = Relation.from_numpy(rows, sorted_by=lex_order(ar))


def refit_shards(data, ndev, new_cap):
    """Re-pad a (ndev * old_cap, ar) blocked array to (ndev * new_cap, ar)
    per shard (capacities only grow, so no valid row is ever sliced off)."""
    arr = np.asarray(data)
    ar = arr.shape[-1]
    arr = arr.reshape(ndev, -1, ar)
    old = arr.shape[1]
    out = np.full((ndev, new_cap, ar), pad_value(arr.dtype), arr.dtype)
    out[:, :min(old, new_cap)] = arr[:, :min(old, new_cap)]
    return out.reshape(ndev * new_cap, ar)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def materialize_distributed(kb, mode: str = "tg", max_rounds: int = 10_000,
                            mesh=None, axis: tuple = ("data",),
                            cfg: DistConfig | None = None,
                            spill: bool = True):
    """Sharded materialization of ``kb`` over ``mesh`` (default: every
    local device on the "data" axis).  ``cfg``, when given, floors the
    planner's per-shard store / delta / exchange-bucket capacities (callers
    that know the instance scale skip the cold-start overflow retries).
    Returns MatStats, or None when the program is outside the plannable
    fragment (the caller falls back to the fused / two-phase executors).

    Capacity overflows retry under a ``RetryBudget``; an exhausted budget
    mid-run ``spill``s the remaining rounds to the two-phase executor
    (``spill=False`` re-raises the ``CapacityError``).

    With ``REPRO_CKPT_DIR`` set, every shard's trimmed store and delta
    rows are checkpointed at round / fixpoint-exit boundaries under one
    coordinator manifest, and the driver restores ELASTICALLY: the
    checkpointed rows are executor- and mesh-neutral, so a run saved at
    one ndev resumes at any other — the restored facts simply re-partition
    through the same full-tuple-hash canonical home the exchanges use."""
    from repro.engine.materialize import MatStats
    if mode not in ("tg", "tg_noopt"):
        return None
    program = kb.program
    plans = {}
    for rule in program.rules:
        plan = compile_rule_plan(rule, kb.dict)
        if plan is None:
            return None
        plans[id(rule)] = plan

    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh()
    ndev = axis_size(mesh, axis)
    preds = tuple(sorted(kb.rels))
    use_prefilter = mode == "tg"
    st = MatStats(mode=mode)
    st.extra.update(dist=True, ndev=ndev)

    # restore BEFORE sharding: maybe_resume rebuilds kb.rels as global
    # host relations, and the ShardedKB constructor below re-partitions
    # them by tuple hash for THIS mesh — that is the whole elastic story
    ck = recovery.EngineCheckpointer(kb, mode, "dist")
    resume = ck.maybe_resume(st)

    skb = ShardedKB(kb, preds, ndev)
    fp = (program_fingerprint((plans[id(r)].key for r in program.rules),
                              sum(kb.rels[p].count for p in preds)),
          "dist", ndev)
    caps = _Caps(fp, {p: (None, skb.per_shard_max[p]) for p in preds},
                 ndev=ndev)
    if ck.caps_state is not None and \
            st.extra.get("resumed_from") == ("dist", ndev):
        # capacity plans are per-shard: only a same-shape dist run's plan
        # transfers; any other source just replans (and re-converges)
        caps.adopt(ck.caps_state)
    if cfg is not None:
        for p in preds:
            caps.store[p] = max(caps.store[p], cfg.shard_cap)
        caps._delta_guess = max(caps._delta_guess, cfg.delta_cap)
        caps._bucket_guess = max(caps._bucket_guess, cfg.bucket_cap)
    skb.pack(caps)

    row_bytes = max((skb.dtype[p].itemsize * skb.arity[p] for p in preds),
                    default=8)
    budget = RetryBudget(caps, row_bytes=row_bytes)

    deltas: dict = {}    # pred -> device (ndev*delta_cap, ar), PAD-padded

    def state_fn():
        """Per-shard checkpoint payloads: each shard's trimmed store rows
        and PAD-filtered delta rows; the base facts ride shard 0."""
        shards = [{} for _ in range(ndev)]
        for p in preds:
            ar = skb.arity[p]
            blocks = np.asarray(skb.data[p]).reshape(ndev, -1, ar)
            for s in range(ndev):
                shards[s][f"store__{p}"] = blocks[s, :int(skb.counts[p][s])]
        for p, d in deltas.items():
            ar = skb.arity[p]
            pad = pad_value(skb.dtype[p])
            blocks = np.asarray(d).reshape(ndev, -1, ar)
            for s in range(ndev):
                rows = blocks[s][blocks[s, :, 0] != pad]
                if len(rows):
                    rows = rows[np.lexsort(rows.T[::-1])]
                shards[s][f"delta__{p}"] = rows
        for p, rel in kb.base.items():
            shards[0][f"base__{p}"] = rel.np_rows()
        return shards

    def fit_delta(pred):
        data = deltas[pred]
        cap = caps.delta_cap(pred)
        if data.shape[0] // ndev == cap:
            return data
        return refit_shards(data, ndev, cap)

    def run_round(active, delta_preds, is_ext=False):
        prefilter = use_prefilter and not is_ext   # no Def. 23 in round 1
        while True:
            sig = _dist_signature(mesh, axis, ndev, preds, caps, active,
                                  delta_preds, prefilter)
            fn, ovf_labels, derived = _cached_program(
                sig, lambda: _build_dist_round(mesh, axis, ndev, preds, caps,
                                               active, delta_preds,
                                               prefilter))
            out = fn(tuple(skb.fit(p, caps.store[p]) for p in preds),
                     tuple(jnp.asarray(skb.counts[p]) for p in preds),
                     tuple(fit_delta(p) for p in delta_preds))
            n_stores, n_counts, n_deltas, n_dcounts, fresh, trg, ovf = out
            # ONE blocking pull per round attempt, independent of ndev:
            # counts + fresh totals + triggers + the overflow vector
            pulled = jax.device_get((n_counts, fresh, trg, ovf))
            ops.HOST_SYNC_STATS.dist_pulls += 1
            cnts, fresh, trg, ovf = pulled
            if not ovf.any():
                budget.ok()
                for p, d, c in zip(preds, n_stores, cnts):
                    skb.data[p] = d
                    skb.counts[p] = np.asarray(c, np.int32)
                st.triggers += int(trg)
                new = {}
                for p, d, ft in zip(derived, n_deltas, fresh):
                    st.derived += int(ft)
                    if int(ft):
                        new[p] = d
                return new
            ops.HOST_SYNC_STATS.dist_retries += 1
            # a rule active at several delta positions repeats its labels;
            # dedupe so a shared capacity doubles once per retry
            budget.overflow(dict.fromkeys(
                l for f, l in zip(ovf, ovf_labels) if f))

    def fit_delta_fix(pred):
        """Delta block for the fixpoint program: the live delta refit to
        the planner cap, or an all-PAD block for quiescent S-preds."""
        if pred not in deltas:
            cap = caps.delta_cap(pred)
            return np.full((ndev * cap, skb.arity[pred]),
                           pad_value(skb.dtype[pred]), skb.dtype[pred])
        return fit_delta(pred)

    def fold_tails(s_preds_, w_datas, wcnts):
        """Fold the per-shard fixpoint tails into the sharded store on the
        host (the rare exit path): concat + lexsort per shard, growing a
        store capacity when a shard fills.  Tail rows were deduped against
        store | tail on their canonical-home shard, so this is a pure
        union of disjoint sorted sets."""
        for p, d, cnts in zip(s_preds_, w_datas, wcnts):
            cnts = np.asarray(cnts, np.int64)
            if not cnts.sum():
                continue
            ar = skb.arity[p]
            tail_blk = np.asarray(d).reshape(ndev, -1, ar)
            store_blk = np.asarray(skb.data[p]).reshape(ndev, -1, ar)
            parts = []
            for s in range(ndev):
                rows = np.concatenate(
                    [store_blk[s, :int(skb.counts[p][s])],
                     tail_blk[s, :int(cnts[s])]])
                if len(rows):
                    rows = rows[np.lexsort(rows.T[::-1])]
                parts.append(rows)
            new_counts = np.array([len(pt) for pt in parts], np.int32)
            cap = caps.store[p]
            while cap < new_counts.max(initial=0):
                cap *= 2
            caps.store[p] = cap
            out = np.full((ndev, cap, ar), pad_value(skb.dtype[p]),
                          skb.dtype[p])
            for s, pt in enumerate(parts):
                out[s, :len(pt)] = pt
            skb.data[p] = out.reshape(ndev * cap, ar)
            skb.counts[p] = new_counts

    def run_fixpoint(live):
        """Finish a linear fixpoint phase inside the while_loop program:
        one host pull per program EXIT (converged / tail fold / capacity
        retry), not per round.  Returns True when the phase ran; False
        when the remaining program is not linear (the caller steps one
        host-driven round instead)."""
        nonlocal deltas
        tail = _linear_tail(int_plans, live)
        if tail is None:
            return False
        s_preds_, active = tail
        o_preds_ = tuple(p for p in preds if p not in s_preds_)
        while True:
            sig = _dist_fix_signature(mesh, axis, ndev, s_preds_, o_preds_,
                                      caps, active, use_prefilter,
                                      max_rounds)
            fn, ovf_labels = _cached_program(
                sig, lambda: _build_dist_fixpoint(
                    mesh, axis, ndev, s_preds_, o_preds_, caps, active,
                    use_prefilter, max_rounds))
            out = fn(tuple(skb.fit(p, caps.store[p]) for p in s_preds_),
                     tuple(fit_delta_fix(p) for p in s_preds_),
                     tuple(skb.fit(p, caps.store[p]) for p in o_preds_),
                     jnp.int32(st.rounds))
            w_datas, w_counts, d_datas, d_counts, rounds, trg, drv, ovf = \
                out
            # ONE blocking pull per fixpoint-program exit: tail + delta
            # counts, the loop's round/trigger/derived totals, and the
            # overflow vector
            pulled = jax.device_get((w_counts, d_counts, rounds, trg, drv,
                                     ovf))
            ops.HOST_SYNC_STATS.dist_pulls += 1
            ops.HOST_SYNC_STATS.dist_fixpoint_pulls += 1
            wcnts, dcnts, rounds, trg, drv, ovf = pulled
            ops.HOST_SYNC_STATS.dist_fixpoint_iters += \
                int(rounds) - st.rounds
            prev_rounds = st.rounds
            st.rounds = int(rounds)
            st.triggers += int(trg)
            st.derived += int(drv)
            deltas = {p: d for p, d, c in zip(s_preds_, d_datas, dcnts)
                      if int(np.asarray(c).sum())}
            fold_tails(s_preds_, w_datas, wcnts)
            if st.rounds > prev_rounds:
                budget.ok()     # the loop advanced: real progress
                progressed[0] = True
            ck.boundary(st, state_fn, caps=caps)
            if not ovf.any():
                return True
            # tail-full exits included: the fold above made room, but
            # without growth a long phase would exit every tail_cap-ish
            # rounds and pulls would scale with the fact count.  Doubling
            # geometrically bounds tail exits at O(log facts) cold and —
            # via the capacity memo — ONE pull per phase warm.
            budget.overflow(dict.fromkeys(
                l for f, l in zip(ovf, ovf_labels) if f))

    progressed = [resume is not None]

    def drive():
        nonlocal deltas
        if resume is not None:
            st.extra["resumed"] = True
            for p, rows in resume.items():
                ar = skb.arity[p]
                tgt = (np_tuple_hash(rows)
                       % np.uint32(ndev)).astype(np.int64)
                parts = []
                for d in range(ndev):
                    part = rows[tgt == d]
                    if len(part):
                        part = part[np.lexsort(part.T[::-1])]
                    parts.append(part)
                caps.seed_delta(p, max(len(pt) for pt in parts))
                cap = caps.delta_cap(p)
                blk = np.full((ndev, cap, ar), pad_value(skb.dtype[p]),
                              skb.dtype[p])
                for d, part in enumerate(parts):
                    blk[d, :len(part)] = part
                deltas[p] = blk.reshape(ndev * cap, ar)
        else:
            # round 1: extensional rules over B
            ext_active = tuple((plans[id(r)], None)
                               for r in program.extensional_rules())
            if ext_active:
                deltas = run_round(ext_active, (), is_ext=True)
            st.rounds = 1
            progressed[0] = True
            ck.boundary(st, state_fn, caps=caps)

        # fixpoint rounds: whole linear phases run inside the compiled
        # while_loop program (one pull per phase exit); non-linear
        # stretches fall back to host-stepped rounds (one compiled program
        # + one scalar pull per round, psum convergence)
        fixpoint_on = ops.dist_fixpoint_enabled()
        while deltas and st.rounds < max_rounds:
            live = tuple(sorted(deltas))
            if fixpoint_on and run_fixpoint(live):
                continue
            active = tuple((plans[id(r)], j) for r in int_rules
                           for j, a in enumerate(r.body)
                           if a.pred in deltas)
            if not active:
                break
            deltas = run_round(active, live)
            st.rounds += 1
            progressed[0] = True
            ck.boundary(st, state_fn, caps=caps)

    int_rules = program.intensional_rules()
    int_plans = [plans[id(r)] for r in int_rules]
    try:
        drive()
    except CapacityError as e:
        if not spill:
            raise
        if not progressed[0]:
            return None     # cold-start overflow: plain fragment fallback
        # graceful degradation: gather the last-good shards back into the
        # kb and run the remaining rounds on the two-phase executor
        from repro.engine.materialize import _fixpoint_rounds
        skb.to_relations(kb)
        seed = {}
        for p, d in deltas.items():
            ar = skb.arity[p]
            pad = pad_value(skb.dtype[p])
            blk = np.asarray(d).reshape(ndev, -1, ar)
            rows = blk.reshape(-1, ar)
            rows = rows[rows[:, 0] != pad]
            if len(rows):
                rows = rows[np.lexsort(rows.T[::-1])]
            seed[p] = Relation.from_numpy(np.ascontiguousarray(rows),
                                          sorted_by=lex_order(ar))
        st.extra["spilled"] = str(e)
        _fixpoint_rounds(kb, st, seed, mode, max_rounds, ck=ck)
        return st

    skb.to_relations(kb)
    caps.memoize()
    ck.final(st, state_fn, caps=caps)
    return st


# ---------------------------------------------------------------------------
# back-compat TC entries (the hand-written TC step this module used to ship
# is gone: TC is now just one more Datalog program over the general executor)
# ---------------------------------------------------------------------------
def _tc_program():
    from repro.core.terms import parse_program
    return parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)


def run_distributed_tc(edges: np.ndarray, mesh,
                       cfg: DistConfig = DistConfig()):
    """Transitive closure of int (n, 2) ``edges`` over the general sharded
    executor; ``cfg``'s capacities floor the planner's.  Returns
    (t_rows (m, 2) int np, count, triggers, rounds)."""
    from repro.core.terms import Atom
    from repro.engine.materialize import EngineKB
    B = [Atom("e", (f"n{int(a)}", f"n{int(b)}")) for a, b in edges]
    kb = EngineKB(_tc_program(), B)
    st = materialize_distributed(kb, mode="tg", max_rounds=cfg.max_rounds,
                                 mesh=mesh, axis=cfg.axis, cfg=cfg)
    rows = np.array(sorted(
        tuple(int(t[1:]) for t in atom.args)
        for atom in kb.decode_facts() if atom.pred == "T"), np.int32)
    return rows, len(rows), st.triggers, st.rounds


def lower_distributed_tc(mesh, cfg: DistConfig = DistConfig()):
    """Dry-run entry: lower one compiled TG round of the TC program (delta
    exchange + planned join + canonical-home absorb) at the configured
    per-shard capacities on a target mesh."""
    from repro.engine.dictionary import Dictionary
    ndev = axis_size(mesh, cfg.axis)
    program = _tc_program()
    dic = Dictionary()
    plans = [compile_rule_plan(r, dic) for r in program.rules]
    preds = ("T", "e")
    caps = _Caps(("dryrun", ndev), {p: (None, 1) for p in preds}, ndev=ndev)
    active = ((plans[1], 0),)                    # T-delta in body position 0
    derived = ("T",)
    labels = _round_ovf_labels(active, True, derived)
    for p in preds:
        caps.store[p] = cfg.shard_cap
    caps.delta["T"] = cfg.delta_cap
    caps.join[(plans[1].key, 0)] = cfg.delta_cap * 4
    for key in _bucket_keys(labels):
        caps.bucket[key] = cfg.bucket_cap
    fn, _, _ = _build_dist_round(mesh, cfg.axis, ndev, preds, caps, active,
                                 ("T",), True)
    s32 = jnp.int32
    store_specs = tuple(jax.ShapeDtypeStruct((ndev * cfg.shard_cap, 2), s32)
                        for _ in preds)
    count_specs = tuple(jax.ShapeDtypeStruct((ndev,), s32) for _ in preds)
    delta_specs = (jax.ShapeDtypeStruct((ndev * cfg.delta_cap, 2), s32),)
    return fn.lower(store_specs, count_specs, delta_specs)
