"""Distributed materialization: a ``shard_map`` executor over the shared
rule-plan IR (beyond-paper: the paper lists distributed KBs as future work).

This is the third physical executor over ``repro.engine.plan``'s
:class:`RulePlan` IR — the same plans the fused single-device executor
compiles, run over hash-partitioned shards.  It handles *arbitrary* Datalog
programs in the plannable fragment (no existentials, connected bodies), not
just the hand-written transitive closure the first version shipped with.

Data model (:class:`ShardedKB` state, kept as device arrays between
rounds): every predicate's store is partitioned across the mesh ``axis`` by
the full-tuple hash — the canonical home of a fact is the shard its hash
picks, which makes dedup and the antijoin against the store purely local —
and each shard keeps its rows lexsorted (the same ``Relation.sorted_by``
store invariant as the single-device engine, so the shared ops cores skip
their sort passes on store inputs).

Each semi-naive / TG round compiles to ONE ``shard_map`` program (cached by
its static signature) that:

  1. walks every active ``(rule plan, delta position)`` with the shared
     chain walker ``_exec_rule_traced``, passing a ``route`` hook that
     re-partitions rows by join key before each join side (and by projected
     head-tuple hash before the Def. 23 antijoin pre-restriction) via the
     fixed-capacity bucket ``_exchange`` (``all_to_all``),
  2. re-partitions each predicate's derivations by full-tuple hash so
     duplicates land on one shard, then runs the shared ``_absorb_traced``
     (lexsort + dedup + antijoin vs the local store shard + incremental
     sorted merge) locally,
  3. reduces convergence scalars with ``psum``: per-pred fresh-fact totals,
     the trigger total, and the overflow vector.

The host pulls exactly one scalar bundle per round
(``HOST_SYNC_STATS.dist_pulls``) regardless of the shard count — the
per-round host-sync cost is independent of ``ndev``.  Overflow follows the
planner contract from ``repro.engine.plan``: every planned capacity (store /
delta / join / exchange bucket, all per shard) carries an in-program flag;
when any fires the round's outputs are discarded, the host doubles exactly
the overflowed buckets, recompiles, and retries the same round
(``HOST_SYNC_STATS.dist_retries``).

Known trade-off: the route hook re-exchanges BOTH sides of every join each
round, including round-invariant store sides — correctness-first; a future
PR can cache per-(pred, join-col) routed copies of static inputs so only
deltas move (the architecture this module exists to enable).

Pallas routing is pinned off here: the kernels are not shard_map-
transformable in interpret mode.

Entry points: ``materialize(kb, mode="tg", backend="dist")`` (or
``REPRO_DIST=1``) routes through :func:`materialize_distributed`, falling
back to the fused / two-phase executors for programs outside the fragment;
``run_distributed_tc`` is the back-compat TC wrapper; ``lower_distributed_tc``
lowers one TC round on a target mesh for the multi-pod dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.engine import ops
from repro.engine.plan import (_MAX_RETRIES, _absorb_traced, _cached_program,
                               _Caps, _exec_rule_traced, compile_rule_plan,
                               program_fingerprint)
from repro.engine.relation import PAD, Relation, lex_order

_NP_PAD = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# hashing (device + host mirrors must agree: initial placement partitions on
# the host with the same function the exchanges use on device)
# ---------------------------------------------------------------------------
def _hash32(x):
    """Cheap int32 mix (Wang hash variant, stays in int32)."""
    x = x.astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return (x ^ (x >> 16)).astype(jnp.uint32)


def _cols_hash(rows, cols):
    """Combined hash of the given columns of each row (uint32)."""
    h = jnp.uint32(0x9E3779B9)
    for c in cols:
        h = _hash32(rows[:, c].astype(jnp.uint32) + h)
    return h


def _tuple_hash(rows):
    return _cols_hash(rows, range(rows.shape[1]))


def _np_hash32(x):
    x = x.astype(np.uint32)
    x = (x ^ (x >> 16)) * np.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * np.uint32(0x846CA68B)
    return x ^ (x >> 16)


def np_tuple_hash(rows: np.ndarray) -> np.ndarray:
    """Host mirror of ``_tuple_hash`` for the initial placement."""
    h = np.uint32(0x9E3779B9)
    out = np.full(rows.shape[0], h, np.uint32)
    for c in range(rows.shape[1]):
        out = _np_hash32(rows[:, c].astype(np.uint32) + out)
    return out


# ---------------------------------------------------------------------------
# fixed-capacity bucket exchange
# ---------------------------------------------------------------------------
def _route_to_buckets(rows, target, ndev, bucket_cap):
    """Pure bucketization half of ``_exchange`` (property-tested on its
    own): scatter rows into per-destination buckets of ``bucket_cap`` rows,
    preserving input order within each bucket (``argsort`` is stable).
    Invalid (PAD) rows are discarded; valid rows beyond a destination's
    capacity are counted.  Returns ((ndev, bucket_cap, ar) buckets,
    overflow_count)."""
    cap, ar = rows.shape
    valid = rows[:, 0] != PAD
    target = jnp.where(valid, target, ndev)          # invalid -> trash bucket
    order = jnp.argsort(target)
    t_sorted = target[order]
    rows_sorted = rows[order]
    pos = jnp.arange(cap) - jnp.searchsorted(t_sorted, t_sorted, side="left")
    slot = jnp.where(t_sorted < ndev, t_sorted * bucket_cap + pos,
                     ndev * bucket_cap)
    overflow = jnp.logical_and(t_sorted < ndev, pos >= bucket_cap)
    slot = jnp.where(overflow, ndev * bucket_cap, slot)
    buckets = jnp.full((ndev * bucket_cap + 1, ar), PAD, jnp.int32)
    buckets = buckets.at[slot].set(jnp.where((t_sorted < ndev)[:, None],
                                             rows_sorted, PAD), mode="drop")
    return (buckets[:ndev * bucket_cap].reshape(ndev, bucket_cap, ar),
            jnp.sum(overflow))


def _exchange(rows, target, ndev, axis, bucket_cap):
    """Fixed-capacity bucket exchange: rows (cap, ar) with target shard ids;
    rows routed via all_to_all; returns ((ndev*bucket_cap, ar) local rows,
    dropped_count) — overflowed rows are counted, so the driver can retry
    with bigger buckets."""
    buckets, overflow = _route_to_buckets(rows, target, ndev, bucket_cap)
    recv = jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    return recv.reshape(ndev * bucket_cap, rows.shape[1]), overflow


@dataclass(frozen=True)
class DistConfig:
    """Fixed capacities for the dry-run / back-compat entries (the general
    executor plans its own per-shard capacities via ``plan._Caps``)."""
    shard_cap: int = 1 << 14         # per-shard store capacity
    delta_cap: int = 1 << 12         # per-shard delta capacity
    bucket_cap: int = 1 << 9         # per-destination exchange bucket
    max_rounds: int = 64
    axis: tuple = ("data",)          # mesh axes facts are partitioned over


def _axis_size(mesh, axis):
    n = 1
    for a in (axis if isinstance(axis, tuple) else (axis,)):
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------------------
# overflow-label enumeration (must mirror the flag order the traced round
# emits: _exec_rule_traced appends pre / left / right exchange flags then
# the join-capacity flag, per join step)
# ---------------------------------------------------------------------------
def _rule_ovf_labels(plan, use_pre):
    labels = []
    for j in range(len(plan.atoms)):
        if use_pre and plan.pre is not None and plan.pre[0] == j:
            labels.append(("bucket", (plan.key, "pre", j)))
        if j >= 1:
            labels.append(("bucket", (plan.key, "jl", j)))
            labels.append(("bucket", (plan.key, "jr", j)))
            labels.append(("join", (plan.key, j - 1)))
    return labels


def _round_ovf_labels(active, use_prefilter, derived):
    labels = []
    for plan, _ in active:
        labels += _rule_ovf_labels(plan, use_prefilter)
    for pred in derived:
        labels += [("bucket", ("absorb", pred)),
                   ("delta", pred), ("store", pred)]
    return labels


def _bucket_keys(labels):
    return tuple(name for kind, name in labels if kind == "bucket")


# ---------------------------------------------------------------------------
# compiled sharded round program
# ---------------------------------------------------------------------------
def _dist_signature(mesh, axis, ndev, preds, caps, active, delta_in,
                    use_prefilter):
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    labels = _round_ovf_labels(active, use_prefilter, derived)
    return ("dist_round", mesh, axis, ndev, preds,
            tuple(caps.store[p] for p in preds),
            tuple((plan.key, jd, tuple(caps.join_cap(plan, i)
                                       for i in range(len(plan.joins))))
                  for plan, jd in active),
            tuple((p, caps.delta_cap(p)) for p in delta_in),
            tuple((p, caps.delta_cap(p)) for p in derived),
            tuple((k, caps.bucket_cap(k)) for k in _bucket_keys(labels)),
            use_prefilter)


def _build_dist_round(mesh, axis, ndev, preds, caps, active, delta_in,
                      use_prefilter):
    """One sharded materialization round as a single jitted shard_map
    program.

    Per-shard inputs: store blocks (tuple-hash partitioned, lexsorted, at
    planner capacities) + per-shard counts, plus the live delta blocks.
    Outputs: new stores / counts / deltas (per shard), the psum'd per-pred
    fresh totals, the round's global trigger total, and the psum'd overflow
    vector.  ``ovf_labels`` names each overflow slot so the driver can
    double exactly the right capacity."""
    derived = tuple(sorted({plan.head_pred for plan, _ in active}))
    ovf_labels = _round_ovf_labels(active, use_prefilter, derived)
    join_caps = {id(plan): tuple(caps.join_cap(plan, i)
                                 for i in range(len(plan.joins)))
                 for plan, _ in active}
    delta_caps = {p: caps.delta_cap(p) for p in derived}
    bucket_caps = {k: caps.bucket_cap(k) for k in _bucket_keys(ovf_labels)}

    def body(store_datas, store_counts, delta_datas):
        stores = dict(zip(preds, store_datas))
        counts = {p: c[0] for p, c in zip(preds, store_counts)}
        deltas = dict(zip(delta_in, delta_datas))
        triggers = jnp.zeros((), jnp.int32)
        ovfs = []
        heads = {}
        for plan, jd in active:
            def route(rows, cols, tag, _pk=plan.key):
                cap = bucket_caps[(_pk, *tag)]
                tgt = (_cols_hash(rows, cols)
                       % jnp.uint32(ndev)).astype(jnp.int32)
                out, dropped = _exchange(rows, tgt, ndev, axis, cap)
                return out, [dropped > 0]
            inputs = [deltas[bp] if j == jd else stores[bp]
                      for j, bp in enumerate(plan.body_preds)]
            pre_data = stores[plan.head_pred] if use_prefilter else None
            head, trg, flags = _exec_rule_traced(
                plan, inputs, pre_data, join_caps[id(plan)], False,
                route=route)
            triggers += trg
            ovfs += flags
            heads.setdefault(plan.head_pred, []).append(head)
        out_deltas, out_dcounts, fresh_tot = [], [], []
        for pred in derived:
            hs = heads[pred]
            cat = hs[0] if len(hs) == 1 else jnp.concatenate(hs, axis=0)
            # canonical-home repartition: duplicates of a tuple (across
            # rules AND shards) all land on the shard its hash picks, so
            # dedup + the antijoin against the store are local
            tgt = (_tuple_hash(cat) % jnp.uint32(ndev)).astype(jnp.int32)
            routed, dropped = _exchange(cat, tgt, ndev, axis,
                                        bucket_caps[("absorb", pred)])
            ovfs.append(dropped > 0)
            ns, nc, delta, nf, (od, os_) = _absorb_traced(
                [routed],
                lambda rows, p=pred: jnp.logical_not(
                    ops.member_mask_core(rows, stores[p])),
                stores[pred], counts[pred], delta_caps[pred], False)
            stores[pred] = ns
            counts[pred] = nc
            out_deltas.append(delta)
            out_dcounts.append(nf)
            fresh_tot.append(jax.lax.psum(nf, axis))
            ovfs += [od, os_]
        ovf_vec = (jnp.stack(ovfs).astype(jnp.int32) if ovfs
                   else jnp.zeros((0,), jnp.int32))
        return (tuple(stores[p] for p in preds),
                tuple(counts[p].reshape(1) for p in preds),
                tuple(out_deltas),
                tuple(nf.reshape(1) for nf in out_dcounts),
                tuple(fresh_tot),
                jax.lax.psum(triggers, axis),
                jax.lax.psum(ovf_vec, axis))

    in_specs = (tuple(P(axis, None) for _ in preds),
                tuple(P(axis) for _ in preds),
                tuple(P(axis, None) for _ in delta_in))
    out_specs = (tuple(P(axis, None) for _ in preds),
                 tuple(P(axis) for _ in preds),
                 tuple(P(axis, None) for _ in derived),
                 tuple(P(axis) for _ in derived),
                 tuple(P() for _ in derived),
                 P(), P())
    fn = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs))
    return fn, ovf_labels, derived


# ---------------------------------------------------------------------------
# sharded store (host-side bookkeeping around the device arrays)
# ---------------------------------------------------------------------------
class ShardedKB:
    """Hash-partitioned store: per predicate, a global (ndev * store_cap,
    ar) device array partitioned over the mesh axis (shard = tuple-hash %
    ndev; each shard's valid rows lexsorted) plus per-shard fill counts on
    the host.  ``fit`` re-pads every shard when the planner doubles a store
    capacity (retry path only — steady-state rounds reuse the arrays the
    previous round produced)."""

    def __init__(self, kb, preds, ndev):
        self.ndev = ndev
        self.arity = {p: kb.rels[p].arity for p in preds}
        self.data = {}               # pred -> device/np (ndev*cap, ar)
        self.counts = {}             # pred -> np (ndev,) int32
        self.per_shard_max = {}
        for p in preds:
            rows = np.asarray(kb.rels[p].np_rows())
            if rows.size:
                rows = np.unique(rows, axis=0)   # set semantics on entry
            tgt = (np_tuple_hash(rows) % np.uint32(ndev)).astype(np.int64) \
                if len(rows) else np.zeros(0, np.int64)
            parts = []
            for d in range(ndev):
                part = rows[tgt == d]
                if len(part):
                    part = part[np.lexsort(part.T[::-1])]
                parts.append(part)
            self.counts[p] = np.array([len(pt) for pt in parts], np.int32)
            self.per_shard_max[p] = int(self.counts[p].max(initial=0))
            self.data[p] = parts     # packed once planner caps exist

    def pack(self, caps):
        """Materialize the per-shard blocks at the planner's store caps."""
        for p, parts in self.data.items():
            cap = caps.store[p]
            out = np.full((self.ndev, cap, self.arity[p]), _NP_PAD, np.int32)
            for d, part in enumerate(parts):
                out[d, :len(part)] = part
            self.data[p] = out.reshape(self.ndev * cap, self.arity[p])

    def fit(self, pred, cap):
        """Current store block re-padded per shard to ``cap`` rows."""
        data = self.data[pred]
        cur = data.shape[0] // self.ndev
        if cur == cap:
            return data
        return refit_shards(data, self.ndev, cap)

    def to_relations(self, kb):
        """Fold the shards back into lexsorted single-device Relations."""
        for p in self.data:
            ar = self.arity[p]
            blocks = np.asarray(self.data[p]).reshape(self.ndev, -1, ar)
            parts = [blocks[d, :int(self.counts[p][d])]
                     for d in range(self.ndev)]
            rows = (np.concatenate(parts) if parts
                    else np.zeros((0, ar), np.int32))
            if len(rows):
                rows = rows[np.lexsort(rows.T[::-1])]
            kb.rels[p] = Relation.from_numpy(rows, sorted_by=lex_order(ar))


def refit_shards(data, ndev, new_cap):
    """Re-pad a (ndev * old_cap, ar) blocked array to (ndev * new_cap, ar)
    per shard (capacities only grow, so no valid row is ever sliced off)."""
    arr = np.asarray(data)
    ar = arr.shape[-1]
    arr = arr.reshape(ndev, -1, ar)
    old = arr.shape[1]
    out = np.full((ndev, new_cap, ar), _NP_PAD, np.int32)
    out[:, :min(old, new_cap)] = arr[:, :min(old, new_cap)]
    return out.reshape(ndev * new_cap, ar)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------
def materialize_distributed(kb, mode: str = "tg", max_rounds: int = 10_000,
                            mesh=None, axis: tuple = ("data",),
                            cfg: DistConfig | None = None):
    """Sharded materialization of ``kb`` over ``mesh`` (default: every
    local device on the "data" axis).  ``cfg``, when given, floors the
    planner's per-shard store / delta / exchange-bucket capacities (callers
    that know the instance scale skip the cold-start overflow retries).
    Returns MatStats, or None when the program is outside the plannable
    fragment (the caller falls back to the fused / two-phase executors)."""
    from repro.engine.materialize import MatStats
    if mode not in ("tg", "tg_noopt"):
        return None
    program = kb.program
    plans = {}
    for rule in program.rules:
        plan = compile_rule_plan(rule, kb.dict)
        if plan is None:
            return None
        plans[id(rule)] = plan

    if mesh is None:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh()
    ndev = _axis_size(mesh, axis)
    preds = tuple(sorted(kb.rels))
    use_prefilter = mode == "tg"
    st = MatStats(mode=mode)
    st.extra.update(dist=True, ndev=ndev)

    skb = ShardedKB(kb, preds, ndev)
    fp = (program_fingerprint((plans[id(r)].key for r in program.rules),
                              sum(kb.rels[p].count for p in preds)),
          "dist", ndev)
    caps = _Caps(fp, {p: (None, skb.per_shard_max[p]) for p in preds},
                 ndev=ndev)
    if cfg is not None:
        for p in preds:
            caps.store[p] = max(caps.store[p], cfg.shard_cap)
        caps._delta_guess = max(caps._delta_guess, cfg.delta_cap)
        caps._bucket_guess = max(caps._bucket_guess, cfg.bucket_cap)
    skb.pack(caps)

    deltas: dict = {}    # pred -> device (ndev*delta_cap, ar), PAD-padded

    def fit_delta(pred):
        data = deltas[pred]
        cap = caps.delta_cap(pred)
        if data.shape[0] // ndev == cap:
            return data
        return refit_shards(data, ndev, cap)

    def run_round(active, delta_preds, is_ext=False):
        prefilter = use_prefilter and not is_ext   # no Def. 23 in round 1
        for _ in range(_MAX_RETRIES):
            sig = _dist_signature(mesh, axis, ndev, preds, caps, active,
                                  delta_preds, prefilter)
            fn, ovf_labels, derived = _cached_program(
                sig, lambda: _build_dist_round(mesh, axis, ndev, preds, caps,
                                               active, delta_preds,
                                               prefilter))
            out = fn(tuple(skb.fit(p, caps.store[p]) for p in preds),
                     tuple(jnp.asarray(skb.counts[p]) for p in preds),
                     tuple(fit_delta(p) for p in delta_preds))
            n_stores, n_counts, n_deltas, n_dcounts, fresh, trg, ovf = out
            # ONE blocking pull per round attempt, independent of ndev:
            # counts + fresh totals + triggers + the overflow vector
            pulled = jax.device_get((n_counts, fresh, trg, ovf))
            ops.HOST_SYNC_STATS.dist_pulls += 1
            cnts, fresh, trg, ovf = pulled
            if not ovf.any():
                for p, d, c in zip(preds, n_stores, cnts):
                    skb.data[p] = d
                    skb.counts[p] = np.asarray(c, np.int32)
                st.triggers += int(trg)
                new = {}
                for p, d, ft in zip(derived, n_deltas, fresh):
                    st.derived += int(ft)
                    if int(ft):
                        new[p] = d
                return new
            ops.HOST_SYNC_STATS.dist_retries += 1
            # a rule active at several delta positions repeats its labels;
            # dedupe so a shared capacity doubles once per retry
            for label in {l for f, l in zip(ovf, ovf_labels) if f}:
                caps.double(label)
        raise RuntimeError("distributed round: capacity retries exhausted")

    # round 1: extensional rules over B
    ext_active = tuple((plans[id(r)], None)
                       for r in program.extensional_rules())
    if ext_active:
        deltas = run_round(ext_active, (), is_ext=True)
    st.rounds = 1

    # fixpoint rounds (host-stepped: one compiled program + one scalar pull
    # per round, psum convergence)
    int_rules = program.intensional_rules()
    while deltas and st.rounds < max_rounds:
        live = tuple(sorted(deltas))
        active = tuple((plans[id(r)], j) for r in int_rules
                       for j, a in enumerate(r.body) if a.pred in deltas)
        if not active:
            break
        deltas = run_round(active, live)
        st.rounds += 1

    skb.to_relations(kb)
    caps.memoize()
    return st


# ---------------------------------------------------------------------------
# back-compat TC entries (the hand-written TC step this module used to ship
# is gone: TC is now just one more Datalog program over the general executor)
# ---------------------------------------------------------------------------
def _tc_program():
    from repro.core.terms import parse_program
    return parse_program("""
        e(X, Y) -> T(X, Y)
        T(X, Y) & e(Y, Z) -> T(X, Z)
    """)


def run_distributed_tc(edges: np.ndarray, mesh,
                       cfg: DistConfig = DistConfig()):
    """Transitive closure of int (n, 2) ``edges`` over the general sharded
    executor; ``cfg``'s capacities floor the planner's.  Returns
    (t_rows (m, 2) int np, count, triggers, rounds)."""
    from repro.core.terms import Atom
    from repro.engine.materialize import EngineKB
    B = [Atom("e", (f"n{int(a)}", f"n{int(b)}")) for a, b in edges]
    kb = EngineKB(_tc_program(), B)
    st = materialize_distributed(kb, mode="tg", max_rounds=cfg.max_rounds,
                                 mesh=mesh, axis=cfg.axis, cfg=cfg)
    rows = np.array(sorted(
        tuple(int(t[1:]) for t in atom.args)
        for atom in kb.decode_facts() if atom.pred == "T"), np.int32)
    return rows, len(rows), st.triggers, st.rounds


def lower_distributed_tc(mesh, cfg: DistConfig = DistConfig()):
    """Dry-run entry: lower one compiled TG round of the TC program (delta
    exchange + planned join + canonical-home absorb) at the configured
    per-shard capacities on a target mesh."""
    from repro.engine.dictionary import Dictionary
    ndev = _axis_size(mesh, cfg.axis)
    program = _tc_program()
    dic = Dictionary()
    plans = [compile_rule_plan(r, dic) for r in program.rules]
    preds = ("T", "e")
    caps = _Caps(("dryrun", ndev), {p: (None, 1) for p in preds}, ndev=ndev)
    active = ((plans[1], 0),)                    # T-delta in body position 0
    derived = ("T",)
    labels = _round_ovf_labels(active, True, derived)
    for p in preds:
        caps.store[p] = cfg.shard_cap
    caps.delta["T"] = cfg.delta_cap
    caps.join[(plans[1].key, 0)] = cfg.delta_cap * 4
    for key in _bucket_keys(labels):
        caps.bucket[key] = cfg.bucket_cap
    fn, _, _ = _build_dist_round(mesh, cfg.axis, ndev, preds, caps, active,
                                 ("T",), True)
    s32 = jnp.int32
    store_specs = tuple(jax.ShapeDtypeStruct((ndev * cfg.shard_cap, 2), s32)
                        for _ in preds)
    count_specs = tuple(jax.ShapeDtypeStruct((ndev,), s32) for _ in preds)
    delta_specs = (jax.ShapeDtypeStruct((ndev * cfg.delta_cap, 2), s32),)
    return fn.lower(store_specs, count_specs, delta_specs)
