"""Adjacent-unique mask Pallas kernel over lexsorted rows.

Given sorted row-major (N, C) int32 data, emits mask[i] = 1 iff row i differs
from row i-1 (and is not padding).  This is the dedup core fused after the
sort (GLog's duplicate elimination).  Block boundaries read one overlapping
row via a shifted input block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.engine.relation import pad_of


def _unique_kernel(cur_ref, prev_ref, out_ref):
    i = pl.program_id(0)
    cur = cur_ref[...]                       # (tile, C)
    prev = prev_ref[...]                     # (tile, C): rows shifted by -1
    neq = jnp.any(cur != prev, axis=1)
    first_global = jnp.logical_and(i == 0,
                                   jax.lax.broadcasted_iota(
                                       jnp.int32, neq.shape, 0) == 0)
    valid = cur[:, 0] != pad_of(cur)
    out_ref[...] = jnp.where(
        jnp.logical_and(valid, jnp.logical_or(neq, first_global)), 1, 0
    ).astype(jnp.int32)


def unique_mask(data, tile: int = 1024, *, interpret: bool = True):
    """data: (N, C) int32 lexsorted (PAD rows last).  Returns (N,) int32."""
    N, C = data.shape
    assert N % tile == 0, (N, tile)
    # shifted copy supplies row i-1; row -1 is a PAD row (compares unequal
    # to any valid row, equal only to other PAD rows which are masked out)
    shifted = jnp.concatenate(
        [jnp.full((1, C), pad_of(data), data.dtype), data[:-1]], axis=0)
    grid = (N // tile,)
    return pl.pallas_call(
        functools.partial(_unique_kernel),
        grid=grid,
        in_specs=[pl.BlockSpec((tile, C), lambda i: (i, 0)),
                  pl.BlockSpec((tile, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(data, shifted)
