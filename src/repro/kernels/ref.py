"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.relation import PAD


def sort_with_payload_ref(keys, vals):
    """Full-sort oracle matching ``kernels.ops.sort_with_payload``: sorted
    keys plus a payload permutation consistent with them."""
    order = jnp.argsort(keys, stable=True)
    return keys[order], vals[order]


def sort_tiles_ref(keys, vals, tile: int):
    n = keys.shape[0]
    kk = keys.reshape(n // tile, tile)
    vv = vals.reshape(n // tile, tile)
    order = jnp.argsort(kk, axis=1)
    return (jnp.take_along_axis(kk, order, axis=1).reshape(n),
            jnp.take_along_axis(vv, order, axis=1).reshape(n))


def merge_pairs_ref(keys, vals, tile: int):
    """Adjacent sorted blocks of tile//2 merged into sorted blocks of tile."""
    return sort_tiles_ref(keys, vals, tile)


def unique_mask_ref(data):
    prev = jnp.concatenate(
        [jnp.full((1, data.shape[1]), PAD, data.dtype), data[:-1]], axis=0)
    neq = jnp.any(data != prev, axis=1)
    neq = neq.at[0].set(True)
    valid = data[:, 0] != PAD
    return jnp.logical_and(neq, valid).astype(jnp.int32)


def probe_sorted_ref(queries, hay_sorted):
    idx = jnp.searchsorted(hay_sorted, queries)
    found = hay_sorted[jnp.clip(idx, 0, hay_sorted.shape[0] - 1)] == queries
    return jnp.logical_and(found, idx < hay_sorted.shape[0]).astype(jnp.int32)
