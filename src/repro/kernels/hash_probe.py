"""Sorted-membership probe Pallas kernel (the Def. 23 antijoin /
redundancy-filter core).

For each query key, a vectorized binary search over a sorted haystack that
lives fully in VMEM (up to ~1M int32 = 4 MB).  The search loop is a static
log2(H) unroll of min/max lane ops — no data-dependent control flow, so the
whole probe block runs on the VPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(q_ref, hay_ref, out_ref, *, steps: int, hay_n: int):
    q = q_ref[...]                           # (tile,)
    hay = hay_ref[...]                       # (hay_n,)
    lo = jnp.zeros(q.shape, jnp.int32)
    hi = jnp.full(q.shape, hay_n, jnp.int32)
    for _ in range(steps):
        mid = (lo + hi) // 2
        v = hay[jnp.clip(mid, 0, hay_n - 1)]
        go = jnp.logical_and(mid < hi, v < q)
        lo = jnp.where(go, mid + 1, lo)
        hi = jnp.where(jnp.logical_and(mid < hi, jnp.logical_not(go)),
                       mid, hi)
    found = hay[jnp.clip(lo, 0, hay_n - 1)] == q
    found = jnp.logical_and(found, lo < hay_n)
    out_ref[...] = found.astype(jnp.int32)


def probe_sorted(queries, hay_sorted, tile: int = 1024, *,
                 interpret: bool = True):
    """queries: (N,) int32; hay_sorted: (H,) sorted int32.
    Returns (N,) int32 membership flags."""
    N = queries.shape[0]
    H = hay_sorted.shape[0]
    assert N % tile == 0
    steps = max(1, math.ceil(math.log2(H + 1)))
    grid = (N // tile,)
    return pl.pallas_call(
        functools.partial(_probe_kernel, steps=steps, hay_n=H),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((H,), lambda i: (0,))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.int32),
        interpret=interpret,
    )(queries, hay_sorted)
