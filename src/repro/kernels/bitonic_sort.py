"""Bitonic sort Pallas kernel (key int32 + payload int32 permutation).

TPU adaptation of GLog's sort-based join/dedup machinery: the inner sorting
network runs entirely in VMEM on power-of-two tiles; compare-exchange steps
are vectorized across lanes (VPU-friendly reshapes — each (k, j) stage is a
reshape + elementwise min/max, no scatter/gather).

The kernel sorts one (TILE,)-sized block per grid cell; larger arrays are
sorted as tiles and merged by ``ops.sort_pairs`` (log-depth pairwise bitonic
merges, each merge itself a kernel call).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmp_exchange(keys, vals, j):
    """One compare-exchange stage at distance j over axis 0 (length n)."""
    n = keys.shape[0]
    kk = keys.reshape(n // (2 * j), 2, j)
    vv = vals.reshape(n // (2 * j), 2, j)
    lo_k, hi_k = kk[:, 0], kk[:, 1]
    lo_v, hi_v = vv[:, 0], vv[:, 1]
    swap = lo_k > hi_k
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)
    keys = jnp.stack([new_lo_k, new_hi_k], axis=1).reshape(n)
    vals = jnp.stack([new_lo_v, new_hi_v], axis=1).reshape(n)
    return keys, vals


def _reverse_blocks(keys, vals, k):
    n = keys.shape[0]
    kk = keys.reshape(n // (2 * k), 2, k)
    vv = vals.reshape(n // (2 * k), 2, k)
    keys = jnp.concatenate([kk[:, :1], kk[:, 1:, ::-1]], axis=1).reshape(n)
    vals = jnp.concatenate([vv[:, :1], vv[:, 1:, ::-1]], axis=1).reshape(n)
    return keys, vals


def _bitonic_kernel(k_ref, v_ref, ko_ref, vo_ref, *, tile: int):
    keys = k_ref[...]
    vals = v_ref[...]
    n = tile
    size = 2
    while size <= n:
        # make bitonic: reverse the second half of each size-block
        keys, vals = _reverse_blocks(keys, vals, size // 2)
        j = size // 2
        while j >= 1:
            keys, vals = _cmp_exchange(keys, vals, j)
            j //= 2
        size *= 2
    ko_ref[...] = keys
    vo_ref[...] = vals


def _merge_kernel(k_ref, v_ref, ko_ref, vo_ref, *, tile: int):
    """Bitonic merge of two sorted halves (second half reversed on the fly)."""
    keys = k_ref[...]
    vals = v_ref[...]
    keys, vals = _reverse_blocks(keys, vals, tile // 2)
    j = tile // 2
    while j >= 1:
        keys, vals = _cmp_exchange(keys, vals, j)
        j //= 2
    ko_ref[...] = keys
    vo_ref[...] = vals


def bitonic_sort_tiles(keys, vals, tile: int, *, interpret: bool = True):
    """Sort each (tile,) block of keys/vals independently.  keys: (n,) int32
    with n % tile == 0."""
    n = keys.shape[0]
    assert n % tile == 0 and (tile & (tile - 1)) == 0
    grid = (n // tile,)
    return pl.pallas_call(
        functools.partial(_bitonic_kernel, tile=tile),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), keys.dtype),
                   jax.ShapeDtypeStruct((n,), vals.dtype)],
        interpret=interpret,
    )(keys, vals)


def bitonic_merge_pairs(keys, vals, tile: int, *, interpret: bool = True):
    """Merge adjacent sorted blocks of length tile//2 into sorted blocks of
    length tile (keys: (n,), n % tile == 0)."""
    n = keys.shape[0]
    assert n % tile == 0 and (tile & (tile - 1)) == 0
    grid = (n // tile,)
    return pl.pallas_call(
        functools.partial(_merge_kernel, tile=tile),
        grid=grid,
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((n,), keys.dtype),
                   jax.ShapeDtypeStruct((n,), vals.dtype)],
        interpret=interpret,
    )(keys, vals)
