"""Jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel bodies run through the Pallas interpreter for correctness validation.
On TPU set ``INTERPRET = False`` (the launch scripts do this when
``jax.default_backend() == 'tpu'``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import bitonic_sort as BS
from repro.kernels import hash_probe as HP
from repro.kernels import unique_mask as UM

INTERPRET = jax.default_backend() != "tpu"


def sort_with_payload(keys, vals, tile: int = 1024):
    """Full sort of (n,) int32 keys + payload: tile-sort kernel + log-depth
    pairwise bitonic merge kernels."""
    n = keys.shape[0]
    assert n % tile == 0 and (n & (n - 1)) == 0
    keys, vals = BS.bitonic_sort_tiles(keys, vals, min(tile, n),
                                       interpret=INTERPRET)
    width = tile * 2
    while width <= n:
        keys, vals = BS.bitonic_merge_pairs(keys, vals, width,
                                            interpret=INTERPRET)
        width *= 2
    return keys, vals


def unique_mask(data, tile: int = 1024):
    n = data.shape[0]
    t = min(tile, n)
    while n % t:
        t //= 2
    return UM.unique_mask(data, tile=t, interpret=INTERPRET)


def probe_sorted(queries, hay_sorted, tile: int = 1024):
    n = queries.shape[0]
    t = min(tile, n)
    while n % t:
        t //= 2
    return HP.probe_sorted(queries, hay_sorted, tile=t, interpret=INTERPRET)
