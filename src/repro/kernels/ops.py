"""Jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel bodies run through the Pallas interpreter for correctness validation.
On TPU set ``INTERPRET = False`` (the launch scripts do this when
``jax.default_backend() == 'tpu'``).

Edge shapes: the engine always calls these on pow-2 capacity buckets, but
the wrappers normalize everything else — empty inputs return immediately,
non-pow-2 sort lengths are padded to the next power of two with key-space
maxima (which sort behind every real key, including PAD sentinels that tie
with them) and sliced back, and tiles are clamped to pow-2 divisors of the
padded length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.relation import next_pow2, pad_of
from repro.kernels import bitonic_sort as BS
from repro.kernels import hash_probe as HP
from repro.kernels import unique_mask as UM

INTERPRET = jax.default_backend() != "tpu"


def _pow2_tile(tile: int, n: int) -> int:
    """Largest pow-2 tile <= min(tile, n); n must itself be pow-2."""
    t = max(1, min(tile, n))
    return 1 << (t.bit_length() - 1)


def sort_with_payload(keys, vals, tile: int = 1024):
    """Full sort of (n,) int32/uint32 keys + payload: tile-sort kernel +
    log-depth pairwise bitonic merge kernels.  Non-pow-2 lengths are padded
    with the key dtype's max; because real keys may equal that sentinel (the
    engine's PAD) and the bitonic network is unstable, the network sorts
    POSITIONS as its payload there — synthetic positions (>= n) are
    compacted out afterwards and the caller's payload gathered back, so the
    returned payload is always a permutation of the caller's, whatever its
    values."""
    n = keys.shape[0]
    if n == 0:
        return keys, vals
    m = next_pow2(n)
    t = _pow2_tile(tile, m)
    if m != n:
        sentinel = jnp.iinfo(keys.dtype).max
        keys_p = jnp.concatenate(
            [keys, jnp.full((m - n,), sentinel, keys.dtype)])
        pos = jnp.arange(m, dtype=jnp.int32)
        keys_p, pos = BS.bitonic_sort_tiles(keys_p, pos, t,
                                            interpret=INTERPRET)
        width = t * 2
        while width <= m:
            keys_p, pos = BS.bitonic_merge_pairs(keys_p, pos, width,
                                                 interpret=INTERPRET)
            width *= 2
        # drop the synthetic entries (position >= n), keeping sorted order:
        # they only interleave with real entries inside the sentinel-key tie
        # group, so an order-preserving compaction is still sorted by key
        keep = pos < n
        slot = jnp.where(keep, jnp.cumsum(keep) - 1, n)
        ks = jnp.zeros((n + 1,), keys.dtype).at[slot].set(keys_p,
                                                          mode="drop")
        perm = jnp.zeros((n + 1,), jnp.int32).at[slot].set(pos, mode="drop")
        return ks[:n], vals[perm[:n]]
    keys, vals = BS.bitonic_sort_tiles(keys, vals, t, interpret=INTERPRET)
    width = t * 2
    while width <= m:
        keys, vals = BS.bitonic_merge_pairs(keys, vals, width,
                                            interpret=INTERPRET)
        width *= 2
    return keys, vals


def _pad_to_tile(n: int, tile: int):
    """(pow-2 tile, padded length that the tile divides)."""
    t = _pow2_tile(tile, n)
    return t, ((n + t - 1) // t) * t


def unique_mask(data, tile: int = 1024):
    n = data.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    t, m = _pad_to_tile(n, tile)
    if m != n:
        # pad with PAD rows: they are masked out by the kernel and sliced off
        data = jnp.concatenate(
            [data, jnp.full((m - n, data.shape[1]), pad_of(data),
                            data.dtype)])
    return UM.unique_mask(data, tile=t, interpret=INTERPRET)[:n]


def probe_sorted(queries, hay_sorted, tile: int = 1024):
    n = queries.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.int32)
    if hay_sorted.shape[0] == 0:
        return jnp.zeros((n,), jnp.int32)
    t, m = _pad_to_tile(n, tile)
    if m != n:
        queries = jnp.concatenate(
            [queries, jnp.zeros((m - n,), queries.dtype)])
    return HP.probe_sorted(queries, hay_sorted, tile=t,
                           interpret=INTERPRET)[:n]
