"""Tokenized data pipeline: deterministic, checkpointable streams.

Two sources:
* ``SyntheticTokens`` — seeded random token stream (throughput tests).
* ``KBLinearizer``   — the paper-integration path: a *materialized KB*
  (engine output) linearized into token sequences
  ``[PRED] [ARG0] ... [SEP]`` for LM pretraining (KG-to-text without a
  natural-language surface form; vocabulary = dictionary ids).

Both expose ``state()``/``restore(state)`` so input position lives in the
checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


class SyntheticTokens:
    def __init__(self, vocab_size: int, batch: int, seq: int, seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = 0

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, st):
        self.step = int(st["step"])
        self.seed = int(st["seed"])

    def next(self):
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        toks = rng.integers(0, self.vocab, (self.batch, self.seq + 1),
                            dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class KBLinearizer:
    """Linearize dictionary-encoded facts into LM token sequences."""

    def __init__(self, kb, batch: int, seq: int, seed: int = 0):
        # token layout: [0]=PAD [1]=SEP, predicates and constants follow
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.step = 0
        preds = sorted(kb.rels)
        pred_id = {p: i for i, p in enumerate(preds)}
        n_pred = len(preds)
        n_const = len(kb.dict)
        n_null = kb.dict.num_nulls
        self.vocab_size = 2 + n_pred + n_const + n_null
        rows = []
        for p, rel in kb.rels.items():
            ar = kb.arities[p]
            for r in rel.np_rows():
                seqt = [2 + pred_id[p]]
                for x in r[:ar]:
                    x = int(x)
                    if x >= 0:
                        seqt.append(2 + n_pred + x)
                    else:
                        seqt.append(2 + n_pred + n_const + (-x) - 1)
                seqt.append(1)
                rows.append(seqt)
        rng = np.random.default_rng(seed)
        rng.shuffle(rows)
        self.stream = np.concatenate([np.asarray(r, np.int32) for r in rows]) \
            if rows else np.zeros(8, np.int32)

    def state(self):
        return {"step": self.step, "seed": self.seed}

    def restore(self, st):
        self.step = int(st["step"])

    def next(self):
        n = self.batch * (self.seq + 1)
        start = (self.step * n) % max(len(self.stream) - n - 1, 1)
        self.step += 1
        if len(self.stream) < n + 1:
            reps = (n + 1) // len(self.stream) + 1
            buf = np.tile(self.stream, reps)[:n + 1]
        else:
            buf = self.stream[start:start + n + 1]
            if len(buf) < n + 1:
                buf = np.concatenate([buf, self.stream[:n + 1 - len(buf)]])
        toks = buf[:n].reshape(self.batch, self.seq + 1)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
