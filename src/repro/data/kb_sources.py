"""KB scenario generators + rule libraries.

The original benchmark data (LUBM dumps, DBpedia, Claros, Reactome, YAGO) is
not redistributable/downloadable offline; these generators produce scenarios
with the same *shape*: a university-domain generator with the standard
LI ⊂ L ⊂ LE rule-set hierarchy (linear translation subset, full Datalog,
plus transitive/symmetric extensions), an iBench-style recursive existential
scenario (ChaseBench analogue), and a ρDF triple scenario (RDFS analogue).
"""
from __future__ import annotations

import numpy as np

from repro.core.terms import Atom, parse_program
from repro.engine.relation import id_range, store_dtype


# ---------------------------------------------------------------------------
# LUBM-flavoured university scenario
# ---------------------------------------------------------------------------
LUBM_LI = parse_program("""
    gradStudent(S, D) -> Student(S)
    ugStudent(S, D) -> Student(S)
    fullProf(P, D) -> Professor(P)
    assocProf(P, D) -> Professor(P)
    assistProf(P, D) -> Professor(P)
    Professor(P) -> Faculty(P)
    lecturer(P, D) -> Faculty(P)
    Faculty(P) -> Employee(P)
    Student(S) -> Person(S)
    Employee(P) -> Person(P)
    teaches(P, C) -> Faculty(P)
    takes(S, C) -> Student(S)
    advisor(S, P) -> Professor(P)
    publication(B, P) -> Author(P)
    headOf(P, D) -> Chair(P)
    Chair(P) -> Professor(P)
""")

LUBM_L = parse_program("""
    gradStudent(S, D) -> Student(S)
    ugStudent(S, D) -> Student(S)
    fullProf(P, D) -> Professor(P)
    assocProf(P, D) -> Professor(P)
    assistProf(P, D) -> Professor(P)
    Professor(P) -> Faculty(P)
    lecturer(P, D) -> Faculty(P)
    Faculty(P) -> Employee(P)
    Student(S) -> Person(S)
    Employee(P) -> Person(P)
    teaches(P, C) -> Faculty(P)
    takes(S, C) -> Student(S)
    advisor(S, P) -> Professor(P)
    publication(B, P) -> Author(P)
    headOf(P, D) -> Chair(P)
    Chair(P) -> Professor(P)
    subOrg(A, B) & subOrg(B, C) -> SubOrgOf(A, C)
    subOrg(A, B) -> SubOrgOf(A, B)
    SubOrgOf(A, B) & subOrg(B, C) -> SubOrgOf(A, C)
    fullProf(P, D) & SubOrgOf(D, U) -> WorksFor(P, U)
    assocProf(P, D) & SubOrgOf(D, U) -> WorksFor(P, U)
    gradStudent(S, D) & SubOrgOf(D, U) -> MemberOf(S, U)
    ugStudent(S, D) & SubOrgOf(D, U) -> MemberOf(S, U)
    WorksFor(P, U) -> MemberOf(P, U)
    takes(S, C) & teaches(P, C) -> TaughtBy(S, P)
    advisor(S, P) & WorksFor(P, U) -> StudentOfUniv(S, U)
    publication(B, P) & advisor(S, P) -> AdvisorPub(S, B)
""")

LUBM_LE = parse_program("""
    gradStudent(S, D) -> Student(S)
    ugStudent(S, D) -> Student(S)
    fullProf(P, D) -> Professor(P)
    assocProf(P, D) -> Professor(P)
    assistProf(P, D) -> Professor(P)
    Professor(P) -> Faculty(P)
    lecturer(P, D) -> Faculty(P)
    Faculty(P) -> Employee(P)
    Student(S) -> Person(S)
    Employee(P) -> Person(P)
    teaches(P, C) -> Faculty(P)
    takes(S, C) -> Student(S)
    advisor(S, P) -> Professor(P)
    publication(B, P) -> Author(P)
    headOf(P, D) -> Chair(P)
    Chair(P) -> Professor(P)
    subOrg(A, B) & subOrg(B, C) -> SubOrgOf(A, C)
    subOrg(A, B) -> SubOrgOf(A, B)
    SubOrgOf(A, B) & subOrg(B, C) -> SubOrgOf(A, C)
    fullProf(P, D) & SubOrgOf(D, U) -> WorksFor(P, U)
    assocProf(P, D) & SubOrgOf(D, U) -> WorksFor(P, U)
    gradStudent(S, D) & SubOrgOf(D, U) -> MemberOf(S, U)
    ugStudent(S, D) & SubOrgOf(D, U) -> MemberOf(S, U)
    WorksFor(P, U) -> MemberOf(P, U)
    takes(S, C) & teaches(P, C) -> TaughtBy(S, P)
    advisor(S, P) & WorksFor(P, U) -> StudentOfUniv(S, U)
    publication(B, P) & advisor(S, P) -> AdvisorPub(S, B)
    takes(S, C) & takes(T, C) -> Classmate(S, T)
    Classmate(S, T) -> Classmate(T, S)
    advisor(S, P) & advisor(T, P) -> Colleague(S, T)
    Colleague(S, T) -> Colleague(T, S)
    Colleague(S, T) & Colleague(T, U) -> Colleague(S, U)
""")


def lubm_facts(n_univ: int = 2, seed: int = 0, scale: int = 1):
    """University-domain EDB.  ~(scale * 600) facts per university."""
    rng = np.random.default_rng(seed)
    facts = []
    add = facts.append
    for u in range(n_univ):
        U = f"univ{u}"
        n_dept = 4 * scale
        for d in range(n_dept):
            D = f"dept{u}_{d}"
            add(Atom("subOrg", (D, U)))
            if d % 3 == 0:
                add(Atom("subOrg", (f"group{u}_{d}", D)))
            profs = []
            for p in range(6):
                P = f"prof{u}_{d}_{p}"
                profs.append(P)
                kind = ("fullProf", "assocProf", "assistProf")[p % 3]
                add(Atom(kind, (P, D)))
                if p == 0:
                    add(Atom("headOf", (P, D)))
            for le in range(2):
                add(Atom("lecturer", (f"lect{u}_{d}_{le}", D)))
            courses = []
            for c in range(8):
                C = f"course{u}_{d}_{c}"
                courses.append(C)
                add(Atom("teaches", (profs[c % len(profs)], C)))
            students = []
            for s in range(25):
                S = f"stud{u}_{d}_{s}"
                students.append(S)
                kind = "gradStudent" if s % 4 == 0 else "ugStudent"
                add(Atom(kind, (S, D)))
                for c in rng.choice(8, size=3, replace=False):
                    add(Atom("takes", (S, courses[c])))
                if s % 4 == 0:
                    add(Atom("advisor", (S, profs[int(rng.integers(6))])))
            for b in range(10):
                add(Atom("publication",
                         (f"pub{u}_{d}_{b}", profs[int(rng.integers(6))])))
    return facts


# ---------------------------------------------------------------------------
# ChaseBench-style recursive existential scenario (iBench STB/ONT analogue)
# ---------------------------------------------------------------------------
CHASEBENCH = parse_program("""
    src1(X, Y) -> exists Z. A(X, Z)
    src2(X, Y) -> B(X, Y)
    A(X, Z) & B(X, Y) -> C(Z, Y)
    C(Z, Y) -> exists W. D(Y, W)
    D(Y, W) & B(X, Y) -> E(X, W)
    E(X, W) -> A(X, W)
    src3(X, Y, Z) -> F(X, Y, Z)
    F(X, Y, Z) & B(X, U) -> G(Y, Z, U)
""")


def chasebench_facts(n: int = 200, seed: int = 1):
    rng = np.random.default_rng(seed)
    facts = []
    dom = [f"o{i}" for i in range(max(8, n // 10))]
    for i in range(n):
        facts.append(Atom("src1", (dom[int(rng.integers(len(dom)))],
                                   dom[int(rng.integers(len(dom)))])))
        facts.append(Atom("src2", (dom[int(rng.integers(len(dom)))],
                                   dom[int(rng.integers(len(dom)))])))
        if i % 3 == 0:
            facts.append(Atom("src3", (dom[int(rng.integers(len(dom)))],
                                       dom[int(rng.integers(len(dom)))],
                                       dom[int(rng.integers(len(dom)))])))
    return list(dict.fromkeys(facts))


# ---------------------------------------------------------------------------
# ρDF (RDFS subset) triple scenario
# ---------------------------------------------------------------------------
RHO_DF = parse_program("""
    sco(A, B) & sco(B, C) -> SCO(A, C)
    sco(A, B) -> SCO(A, B)
    SCO(A, B) & sco(B, C) -> SCO(A, C)
    spo(A, B) & spo(B, C) -> SPO(A, C)
    spo(A, B) -> SPO(A, B)
    SPO(A, B) & spo(B, C) -> SPO(A, C)
    type(X, A) & SCO(A, B) -> Type(X, B)
    type(X, A) -> Type(X, A)
    triple(S, P, O) & SPO(P, Q) -> Triple(S, Q, O)
    triple(S, P, O) -> Triple(S, P, O)
    Triple(S, P, O) & dom(P, A) -> Type(S, A)
    Triple(S, P, O) & range(P, A) -> Type(O, A)
""")


def rho_df_facts(n_classes: int = 40, n_props: int = 15,
                 n_instances: int = 600, seed: int = 2):
    """Random taxonomy (forest) + instance triples (YAGO-ish shape)."""
    rng = np.random.default_rng(seed)
    facts = []
    for c in range(1, n_classes):
        parent = int(rng.integers(0, c))
        facts.append(Atom("sco", (f"C{c}", f"C{parent}")))
    for p in range(1, n_props):
        parent = int(rng.integers(0, p))
        facts.append(Atom("spo", (f"P{p}", f"P{parent}")))
        facts.append(Atom("dom", (f"P{p}", f"C{int(rng.integers(n_classes))}")))
        facts.append(Atom("range", (f"P{p}",
                                    f"C{int(rng.integers(n_classes))}")))
    for i in range(n_instances):
        facts.append(Atom("type", (f"i{i}", f"C{int(rng.integers(n_classes))}")))
        facts.append(Atom("triple", (f"i{int(rng.integers(n_instances))}",
                                     f"P{int(rng.integers(n_props))}",
                                     f"i{int(rng.integers(n_instances))}")))
    return facts


# ---------------------------------------------------------------------------
# transitive closure — the canonical deep-fixpoint / exchange-heavy scenarios
# ---------------------------------------------------------------------------
TC = parse_program("""
    e(X, Y) -> T(X, Y)
    T(X, Y) & e(Y, Z) -> T(X, Z)
""")


def tc_chain_facts(n_chain: int = 128, chord_every: int = 8):
    """Deep-chain TC base: an ``n_chain``-edge path plus sparse back-chords
    (``(3i+2, i)`` every ``chord_every`` nodes).  The closure needs
    O(n_chain) rounds — the scenario that separates O(phases) host sync
    from O(rounds)."""
    edges = [(i, i + 1) for i in range(n_chain)] + \
        [(3 * i + 2, i) for i in range(n_chain // chord_every)]
    return [Atom("e", (f"v{a}", f"v{b}")) for a, b in edges]


def tc_random_facts(n_nodes: int = 400, n_edges: int = 1200, seed: int = 3):
    """Wide random-graph TC base: few rounds, large joins and deltas, so
    the per-round exchange/join cost — not the round count — dominates
    (the scenario where sharding the sort/merge work pays off).  Edge
    endpoints are generated at the dictionary's id dtype so the data
    round-trips through the narrow store without a silent upcast."""
    rng = np.random.default_rng(seed)
    edges = np.unique(
        rng.integers(0, n_nodes, (n_edges, 2)).astype(store_dtype()), axis=0)
    return [Atom("e", (f"v{a}", f"v{b}")) for a, b in edges.tolist()]


# ---------------------------------------------------------------------------
# streamed (chunked-ndarray) scale scenarios: base facts yielded as
# ("pred", (n, ar) int ndarray) chunks for EngineKB.from_stream — a 10^8-fact
# KB never exists as decoded Python tuples
# ---------------------------------------------------------------------------
def _check_node_range(n_nodes: int, dtype) -> np.dtype:
    dt = np.dtype(dtype) if dtype is not None else store_dtype()
    lo, hi = id_range(dt)
    if n_nodes - 1 > hi:
        raise OverflowError(
            f"{n_nodes} nodes exceed the {dt} store id range [0, {hi}]; "
            "use a wider REPRO_STORE_DTYPE")
    return dt


def tc_wide_chunks(n_chains: int, chain_len: int = 4,
                   chunk_rows: int = 1 << 20, dtype=None):
    """Wide-TC base as edge chunks: ``n_chains`` DISJOINT chains of
    ``chain_len`` edges each.  The closure adds exactly
    ``chain_len * (chain_len + 1) / 2`` facts per chain (see
    :func:`tc_wide_total`), so the total fact count scales linearly with
    ``n_chains`` while the fixpoint stays ``chain_len`` rounds deep — the
    regime where sort/merge/probe throughput, not round count, is the
    engine's cost.  Yields ``("e", (n, 2) ndarray)`` chunks of at most
    ``chunk_rows`` rows in the store id dtype."""
    dt = _check_node_range(n_chains * (chain_len + 1), dtype)
    total = n_chains * chain_len
    start = 0
    while start < total:
        stop = min(start + chunk_rows, total)
        idx = np.arange(start, stop, dtype=np.int64)
        chain, off = np.divmod(idx, chain_len)
        src = chain * (chain_len + 1) + off
        yield "e", np.stack([src, src + 1], axis=1).astype(dt)
        start = stop


def tc_wide_total(n_chains: int, chain_len: int = 4) -> int:
    """Total fact count (base edges + closure) of the tc_wide scenario."""
    return n_chains * chain_len + n_chains * chain_len * (chain_len + 1) // 2


def tc_random_chunks(n_nodes: int, n_edges: int, seed: int = 3,
                     chunk_rows: int = 1 << 20, dtype=None):
    """Random-graph TC base as edge chunks (duplicate edges possible across
    chunks — the streamed ingest dedups them against the store)."""
    dt = _check_node_range(n_nodes, dtype)
    rng = np.random.default_rng(seed)
    left = n_edges
    while left > 0:
        n = min(left, chunk_rows)
        yield "e", rng.integers(0, n_nodes, (n, 2)).astype(dt)
        left -= n


# ---------------------------------------------------------------------------
# linear scenarios (LI) helper: the linear sub-programs
# ---------------------------------------------------------------------------
def linear_subset(program):
    from repro.core.terms import Program
    return Program([r for r in program.rules if r.is_linear])


SCENARIOS = {
    "LUBM-LI": (LUBM_LI, lambda scale: lubm_facts(n_univ=scale)),
    "LUBM-L": (LUBM_L, lambda scale: lubm_facts(n_univ=scale)),
    "LUBM-LE": (LUBM_LE, lambda scale: lubm_facts(n_univ=scale)),
    "CHASEBENCH": (CHASEBENCH, lambda scale: chasebench_facts(n=100 * scale)),
    "RHO-DF": (RHO_DF, lambda scale: rho_df_facts(
        n_classes=20 * scale, n_instances=300 * scale)),
    "TC-CHAIN": (TC, lambda scale: tc_chain_facts(n_chain=64 * scale)),
    "TC-RAND": (TC, lambda scale: tc_random_facts(
        n_nodes=200 * scale, n_edges=600 * scale)),
}

# streamed counterparts: (program, scale -> iterator of (pred, ndarray)
# chunks) for EngineKB.from_stream — scale is the TOTAL fact target (base +
# closure for TC-WIDE), so bases never exist as python tuples
STREAM_SCENARIOS = {
    "TC-WIDE": (TC, lambda total: tc_wide_chunks(max(total // 14, 1))),
    "TC-RAND": (TC, lambda total: tc_random_chunks(
        n_nodes=max(total // 3, 1), n_edges=total)),
}
