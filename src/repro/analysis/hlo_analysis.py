"""Trip-count-aware cost analysis of compiled (post-SPMD, post-fusion) HLO.

``compiled.cost_analysis()`` on XLA:CPU counts each while-loop body ONCE,
which silently under-reports every ``lax.scan``/``lax.map`` (layer stacks,
flash-attention chunk loops, CE-loss chunk loops) by its trip count.  This
module re-derives FLOPs / HBM bytes / collective bytes by walking the HLO
call graph and multiplying while bodies by their (statically known) trip
counts.

Method
------
* FLOPs: exact for ``dot`` (2 * prod(result) * prod(contracting dims));
  elementwise fusions counted at 1 FLOP per output element (dots dominate).
* Bytes: post-fusion HBM traffic approximation — for every materializing op
  (fusion, dot, copy, slice ops, collectives, ...) sum operand + result
  buffer sizes.  get-tuple-element / tuple / parameter / bitcast / constant
  are free.
* Collectives: per-kind result-buffer bytes; all-reduce weighted 2x (ring =
  reduce-scatter + all-gather); reduce-scatter counts operand bytes.  Async
  pairs counted at the -done op.
* while: all three metrics multiply by the trip count, parsed from the cond
  computation's scalar s32 constant (the jax scan lowering pattern).
* conditional: true branch assumed taken (max over branches for flops).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")


def _shape_list(seg: str):
    """All (dtype, dims) array shapes in a type segment."""
    out = []
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, d))
    return out


def _nbytes(seg: str) -> int:
    total = 0
    for dt, dims in _shape_list(seg):
        n = 1
        for x in dims:
            n *= x
        total += n * _DTYPE_BYTES[dt]
    return total


def _nelems(seg: str) -> int:
    total = 0
    for _, dims in _shape_list(seg):
        n = 1
        for x in dims:
            n *= x
        total += n
    return total


@dataclass
class Op:
    name: str
    result_seg: str
    opcode: str
    rest: str            # everything after '(' (operands + attrs)


@dataclass
class Computation:
    name: str
    is_entry: bool
    ops: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # %name -> result type seg
    root: str = ""                               # name of the ROOT op


_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_hlo(text: str) -> dict:
    comps = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        if cur is None:
            if (s.startswith("ENTRY") or s.startswith("%")) and s.endswith("{"):
                m = _COMP_HDR_RE.match(s.lstrip("ENTRY ").strip())
                if m:
                    cur = Computation(name=m.group(1),
                                      is_entry=s.startswith("ENTRY"))
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(s)
        if m:
            op = Op(name=m.group(1), result_seg=m.group(2),
                    opcode=m.group(3), rest=m.group(4))
            cur.ops.append(op)
            cur.shapes["%" + op.name] = op.result_seg
            if s.lstrip().startswith("ROOT"):
                cur.root = op.name
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(cond: Computation) -> int:
    """jax scan lowering: cond compares induction var (starting at 0) LT a
    scalar s32 constant.  Heuristic: the max scalar int constant in cond."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant" and op.result_seg.startswith(("s32[]", "s64[]", "u32[]")):
            m = re.match(r"\s*([0-9]+)\)?", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _nelems(op.result_seg)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not m:
        return 2.0 * res
    cdims = [int(x) for x in m.group(1).split(",") if x]
    # first operand = lhs
    ops_m = _OPERAND_RE.findall(op.rest.split(")", 1)[0] if ")" in op.rest
                                else op.rest)
    k = 1
    if ops_m:
        lhs_seg = comp.shapes.get("%" + ops_m[0])
        if lhs_seg:
            shapes = _shape_list(lhs_seg)
            if shapes:
                dims = shapes[0][1]
                for c in cdims:
                    if c < len(dims):
                        k *= dims[c]
    return 2.0 * res * k


def _conv_flops(op: Op, comp: Computation) -> float:
    # 2 * prod(result) * (kernel_elems * in_ch / groups): approximate via rhs
    res = _nelems(op.result_seg)
    ops_m = _OPERAND_RE.findall(op.rest)
    k = 1
    if len(ops_m) >= 2:
        rhs_seg = comp.shapes.get("%" + ops_m[1])
        if rhs_seg:
            shapes = _shape_list(rhs_seg)
            if shapes:
                dims = shapes[0][1]
                n = 1
                for x in dims:
                    n *= x
                # kernel total / out_features ~ per-output fan-in
                out_feats = max(dims[-1], 1) if dims else 1
                k = max(n // out_feats, 1)
    return 2.0 * res * k


class HloCost:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo = {}
        self.entry = next((c for c in self.comps.values() if c.is_entry), None)

    def _operand_names(self, op: Op):
        head = op.rest.split("),", 1)[0]
        return _OPERAND_RE.findall(head)

    def _param_read_bytes(self, called: Computation):
        """Per-parameter-index effective read bytes inside a fusion body.

        * consumed only by (dynamic-)slice/gather -> just the slice bytes
          (XLA fuses scan xs indexing into loop fusions; counting the full
          stacked operand would overcount by the layer count);
        * consumed only as the *target* (operand 0) of dynamic-update-slice
          -> 0 bytes (in-place update, nothing is read).
        """
        key = ("_param_reads", called.name)
        if key in self._memo:
            return self._memo[key]
        idx_to_name = {}
        for o in called.ops:
            if o.opcode == "parameter":
                m = re.match(r"\s*([0-9]+)", o.rest)
                if m:
                    idx_to_name[int(m.group(1))] = o.name
        out = {}
        for idx, pname in idx_to_name.items():
            full = _nbytes(called.shapes.get("%" + pname, ""))
            consumers = [o for o in called.ops
                         if o.opcode != "parameter"
                         and re.search(r"%" + re.escape(pname) + r"\b", o.rest)]
            b = 0
            cheap = True
            for o in consumers:
                if o.opcode in ("dynamic-slice", "slice", "gather", "bitcast"):
                    b += _nbytes(o.result_seg)
                elif o.opcode == "dynamic-update-slice":
                    ops_o = self._operand_names(o)
                    if ops_o and ops_o[0] == pname:
                        continue        # in-place target: no read
                    b += full
                else:
                    cheap = False
                    break
            out[idx] = b if (cheap and consumers) else full
        self._memo[key] = out
        return out

    def _fusion_write_bytes(self, op: Op, called: Computation) -> int:
        """Effective bytes written by a fusion: dynamic-update-slice roots
        write only the update region (buffers alias in place)."""
        root = next((o for o in called.ops if o.name == called.root), None)
        if root is None and called.ops:
            root = called.ops[-1]

        def write_of(o: Op) -> int:
            if o is None:
                return _nbytes(op.result_seg)
            if o.opcode == "dynamic-update-slice":
                ns = self._operand_names(o)
                if len(ns) >= 2:
                    seg = called.shapes.get("%" + ns[1])
                    if seg:
                        return _nbytes(seg)
            if o.opcode == "tuple":
                total = 0
                for n in self._operand_names(o):
                    src = next((x for x in called.ops if x.name == n), None)
                    total += write_of(src)
                return total
            return _nbytes(o.result_seg)

        return write_of(root)

    def _operand_bytes(self, op: Op, comp: Computation) -> int:
        names = self._operand_names(op)
        reads = None
        if op.opcode == "fusion":
            cm = _CALLS_RE.search(op.rest)
            if cm and cm.group(1) in self.comps:
                reads = self._param_read_bytes(self.comps[cm.group(1)])
        total = 0
        for i, name in enumerate(names):
            seg = comp.shapes.get("%" + name)
            if not seg:
                continue
            full = _nbytes(seg)
            if reads is not None and i in reads:
                total += min(full, reads[i])
            else:
                total += full
        return total

    def _analyze(self, comp_name: str):
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        if comp is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_count": 0}
        flops = 0.0
        mem = 0.0
        coll = {k: 0.0 for k in _COLL_KINDS}
        coll_count = 0
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            if oc == "while":
                cm = _COND_RE.search(op.rest)
                bm = _BODY_RE.search(op.rest)
                trip = 1
                if cm and cm.group(1) in self.comps:
                    trip = _trip_count(self.comps[cm.group(1)])
                if bm:
                    sub = self._analyze(bm.group(1))
                    flops += trip * sub["flops"]
                    mem += trip * sub["bytes"]
                    for k, v in sub["coll"].items():
                        coll[k] += trip * v
                    coll_count += trip * sub["coll_count"]
                continue
            if oc == "conditional":
                bm = _BRANCH_RE.search(op.rest)
                names = []
                if bm:
                    names = [b.strip().lstrip("%")
                             for b in bm.group(1).split(",")]
                else:
                    for key in ("true_computation", "false_computation"):
                        m2 = re.search(key + r"=%?([\w.\-]+)", op.rest)
                        if m2:
                            names.append(m2.group(1))
                if names:
                    subs = [self._analyze(b) for b in names]
                    if subs:
                        best = max(subs, key=lambda s: s["flops"])
                        flops += best["flops"]
                        mem += best["bytes"]
                        for k, v in best["coll"].items():
                            coll[k] += v
                        coll_count += best["coll_count"]
                continue
            if oc in ("call", "async-start"):
                tm = _TO_APPLY_RE.search(op.rest) or _CALLS_RE.search(op.rest)
                if tm:
                    sub = self._analyze(tm.group(1))
                    flops += sub["flops"]
                    mem += sub["bytes"]
                    for k, v in sub["coll"].items():
                        coll[k] += v
                    coll_count += sub["coll_count"]
                continue

            # ---- collectives ----
            base = oc
            async_done = False
            for k in _COLL_KINDS:
                if oc.startswith(k):
                    base = k
                    async_done = oc.endswith("-done")
                    break
            if base in _COLL_KINDS:
                if oc.endswith("-start"):
                    continue   # counted at -done
                if base == "reduce-scatter":
                    b = self._operand_bytes(op, comp)
                else:
                    b = _nbytes(op.result_seg)
                if base == "all-reduce":
                    b *= 2     # ring all-reduce = RS + AG
                coll[base] += b
                coll_count += 1
                mem += _nbytes(op.result_seg) + self._operand_bytes(op, comp)
                continue

            # ---- flops ----
            if oc == "dot":
                flops += _dot_flops(op, comp)
            elif oc == "convolution":
                flops += _conv_flops(op, comp)
            elif oc == "fusion":
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    sub = self._analyze(cm.group(1))
                    # fusion bodies: count inner dots exactly; elementwise at
                    # 1 flop/elem of fusion output
                    flops += sub["flops"] + _nelems(op.result_seg)
                    for k, v in sub["coll"].items():
                        coll[k] += v
                    coll_count += sub["coll_count"]
            elif oc in ("reduce", "reduce-window", "select-and-scatter",
                        "sort", "scatter", "gather", "cholesky",
                        "triangular-solve"):
                flops += _nelems(op.result_seg)

            # ---- bytes (materializing ops only) ----
            # slice-type ops move only the slice, not the full operand;
            # dynamic-update-slice writes only the update region (in-place).
            if oc in ("dynamic-slice", "slice", "broadcast", "pad", "gather",
                      "reshape", "transpose", "reverse", "iota"):
                mem += 2 * _nbytes(op.result_seg)
            elif oc == "dynamic-update-slice":
                upd = 0
                head = op.rest.split("),", 1)[0]
                names = _OPERAND_RE.findall(head)
                if len(names) >= 2:
                    seg = comp.shapes.get("%" + names[1])
                    if seg:
                        upd = _nbytes(seg)
                mem += 2 * upd
            elif oc == "scatter":
                mem += 2 * _nbytes(op.result_seg)
            elif oc == "fusion":
                cm = _CALLS_RE.search(op.rest)
                called = self.comps.get(cm.group(1)) if cm else None
                if called is not None:
                    mem += (self._fusion_write_bytes(op, called)
                            + self._operand_bytes(op, comp))
                else:
                    mem += _nbytes(op.result_seg) + self._operand_bytes(op, comp)
            else:
                mem += _nbytes(op.result_seg) + self._operand_bytes(op, comp)
        out = {"flops": flops, "bytes": mem, "coll": coll,
               "coll_count": coll_count}
        self._memo[comp_name] = out
        return out

    def totals(self):
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "coll": {}, "coll_count": 0}
        # inner fusion computations' dot flops are reachable from entry via
        # fusion 'calls='; while bodies via while ops
        return self._analyze(self.entry.name)


def analyze_text(text: str) -> dict:
    hc = HloCost(text)
    t = hc.totals()
    coll_total = sum(t["coll"].values())
    return {"flops": t["flops"], "bytes": t["bytes"],
            "coll": t["coll"], "coll_bytes": coll_total,
            "coll_count": t["coll_count"]}
