"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch, shape, mesh) cell, all in seconds:

    compute    = HLO_FLOPs            / (chips * PEAK_FLOPS)
    memory     = HLO_bytes            / (chips * HBM_BW)
    collective = collective_bytes     / (chips * LINK_BW)

``cost_analysis()`` reports *per-device-program* flops/bytes; we multiply by
chip count to get fleet totals, then divide by fleet capability — i.e. the
terms are per-chip step latencies assuming perfect overlap within each class.

collective_bytes is NOT in cost_analysis: we parse the compiled HLO text and
sum operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.  Bytes are per-device payloads (shapes in the post-
SPMD module are per-device).
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field

# TPU v5e-like hardware constants (per brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per link (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\],{} ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _parse_result_bytes(line: str) -> int:
    """Sum byte sizes of the result shapes on an HLO op line."""
    lhs = line.split("=", 1)[0] if "=" in line else ""
    rhs = line.split("=", 1)[1] if "=" in line else line
    # result type annotation sits right after '=' and before the op name
    m = re.match(r"\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[0-9,]*\})?)", rhs)
    if not m:
        return 0
    seg = m.group(1)
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-kind result bytes of collective ops in (post-SPMD) HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # avoid double count of async pairs (count the -start)
        kind = m.group(1).lower()
        b = _parse_result_bytes(line)
        out[kind] += b
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-gather", "all-reduce", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


@dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                 # fleet total
    hlo_bytes: float                 # fleet total
    coll_bytes: float                # per-chip payload total
    coll_detail: dict
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    mem_per_device: float

    def to_json(self):
        return self.__dict__


def analyze(arch, shape, mesh_name, chips, cost, hlo_text, model_flops,
            mem_stats=None) -> RooflineResult:
    """Roofline terms from the trip-count-aware HLO walk (hlo_analysis).

    ``cost_analysis()`` numbers are kept in ``coll_detail['xla_cost']`` for
    reference, but XLA:CPU counts while bodies once, so the corrected walk is
    authoritative (see hlo_analysis docstring).
    """
    from repro.analysis import hlo_analysis as HA
    t = HA.analyze_text(hlo_text)
    per_dev_flops = float(t["flops"])
    per_dev_bytes = float(t["bytes"])
    cb = dict(t["coll"])
    cb["total"] = float(t["coll_bytes"])
    cb["count"] = int(t["coll_count"])
    cb["xla_cost"] = {"flops": float(cost.get("flops", 0.0)),
                      "bytes accessed": float(cost.get("bytes accessed", 0.0))}
    hlo_flops = per_dev_flops * chips
    hlo_bytes = per_dev_bytes * chips
    compute_s = per_dev_flops / PEAK_FLOPS
    memory_s = per_dev_bytes / HBM_BW
    collective_s = cb["total"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / hlo_flops if hlo_flops else 0.0
    return RooflineResult(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
        coll_bytes=cb["total"], coll_detail=cb,
        model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, useful_ratio=useful,
        mem_per_device=float(mem_stats) if mem_stats is not None else 0.0)


# ---------------------------------------------------------------------------
# engine rooflines: per-op-class bytes/flops-per-fact of the sorted-store
# cores (sort / probe / absorb) and of the fused executor's compiled programs
# ---------------------------------------------------------------------------
def _lowered_walk(fn, *avals) -> dict:
    import jax
    from repro.analysis import hlo_analysis as HA
    compiled = jax.jit(fn).lower(*avals).compile()
    return HA.analyze_text(compiled.as_text())


def engine_op_roofline(n_rows: int, arity: int = 2, dtype=None,
                       pallas=None) -> dict:
    """Lower the three dominant sorted-store cores at the capacity the
    planner would pick for ``n_rows`` facts and report bytes/flops per fact
    per op class: ``sort`` (lexsort_core), ``probe`` (member_mask_core),
    ``absorb`` (merge_core).  These are the unit costs the BENCH_scale
    trajectory is judged against."""
    import jax
    import numpy as np
    from repro.engine import ops as EO
    from repro.engine.relation import next_pow2, store_dtype

    dt = np.dtype(dtype) if dtype is not None else store_dtype()
    cap = next_pow2(max(n_rows, 1))
    rows = jax.ShapeDtypeStruct((cap, arity), dt)
    i32 = jax.ShapeDtypeStruct((), np.int32)

    def cell(t):
        denom = max(n_rows, 1)
        return {"flops": t["flops"], "bytes": t["bytes"],
                "flops_per_fact": t["flops"] / denom,
                "bytes_per_fact": t["bytes"] / denom}

    out = {"n_rows": n_rows, "capacity": cap, "arity": arity,
           "dtype": str(dt)}
    out["sort"] = cell(_lowered_walk(
        lambda d: EO.lexsort_core(d, pallas), rows))
    out["probe"] = cell(_lowered_walk(EO.member_mask_core, rows, rows))
    out["absorb"] = cell(_lowered_walk(EO.merge_core, rows, rows, i32, i32))
    return out


def engine_fused_roofline(kb, total_facts: int, mode: str = "tg"):
    """Trip-count-aware walk over the fused executor's compiled round and
    fixpoint programs for ``kb`` (see fused.lower_fused_programs): flops,
    bytes, per-fact unit costs and arithmetic intensity per program.
    Returns None when the program leaves the fused fragment."""
    from repro.analysis import hlo_analysis as HA
    from repro.engine.fused import lower_fused_programs

    arts = lower_fused_programs(kb, mode=mode)
    if not arts:
        return None
    denom = max(total_facts, 1)
    out = {}
    for name, (text, cost) in arts.items():
        t = HA.analyze_text(text)
        # static sort-op count: the executor's sort passes live inside the
        # compiled program, invisible to the host-side SORT_STATS counters
        sort_ops = sum(1 for c in HA.parse_hlo(text).values()
                       for op in c.ops if op.opcode == "sort")
        out[name] = {
            "flops": t["flops"], "bytes": t["bytes"],
            "sort_ops_static": sort_ops,
            "flops_per_fact": t["flops"] / denom,
            "bytes_per_fact": t["bytes"] / denom,
            "intensity_flops_per_byte": (t["flops"] / t["bytes"]
                                         if t["bytes"] else 0.0),
            "xla_cost": {"flops": float(cost.get("flops", 0.0)),
                         "bytes_accessed": float(
                             cost.get("bytes accessed", 0.0))},
        }
    return out


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D train (N=active for MoE), 2*N*D forward-only."""
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
