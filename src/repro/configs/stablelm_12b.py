"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b family; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13_824,
    vocab_size=100_352,
    mlp_type="swiglu",
    norm_type="layernorm",
    use_bias=False,
    rope_theta=10_000.0,
    microbatches=4,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, attn_chunk=16, loss_chunk=16,
)
