"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT frontend is a STUB — input_specs() provides precomputed patch embeddings
interleaved with token embeddings.  [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_bias=True,            # Qwen2 backbone uses qkv bias
    rope_theta=1_000_000.0,
    input_mode="embeddings",  # modality frontend stub
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, attn_chunk=16, loss_chunk=16,
)
