"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.
GQA, RoPE, gelu MLP, bias terms.  [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
    rope_theta=100_000.0,
    microbatches=4,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, attn_chunk=16, loss_chunk=16,
)
