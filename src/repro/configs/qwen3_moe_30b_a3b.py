"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=64,
    d_ff=768,                # == moe expert width (all layers MoE)
    vocab_size=151_936,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    use_qk_norm=True,
    rope_theta=1_000_000.0,
    num_experts=128,
    num_shared_experts=0,
    top_k=8,
    moe_d_ff=768,
    capacity_factor=1.25,
    microbatches=2,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256, num_experts=8, top_k=2, moe_d_ff=32,
    attn_chunk=16, loss_chunk=16,
)
