"""Config system: model configs, shape presets, and the architecture registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(name)`` resolves it.  Shape presets (the four
assigned input-shape cells) live here as ``ShapeConfig``.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- architectural details -------------------------------------------
    mlp_type: str = "swiglu"        # swiglu | squared_relu | gelu
    norm_type: str = "rmsnorm"      # rmsnorm | layernorm
    use_bias: bool = False
    parallel_block: bool = False    # command-r style parallel attn+mlp
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    use_qk_norm: bool = False
    is_encoder: bool = False        # encoder-only (no causal mask, no decode)
    input_mode: str = "tokens"      # tokens | embeddings (modality-frontend stub)

    # --- attention --------------------------------------------------------
    attn_type: str = "gqa"          # gqa | mla | none
    # MLA (deepseek-v3) parameters
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_d_ff: int = 0             # deepseek: dense FFN width for first layers
    num_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSM (mamba) --------------------------------------------------------
    ssm_version: int = 0            # 0 = none, 1 = mamba1, 2 = mamba2
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0            # mamba1
    ssm_head_dim: int = 64          # mamba2
    ssm_ngroups: int = 1            # mamba2

    # --- hybrid (zamba2) ------------------------------------------------------
    hybrid_attn_every: int = 0      # apply the shared attention block every N layers

    # --- MTP (deepseek) -------------------------------------------------------
    mtp_depth: int = 0              # extra multi-token-prediction heads

    # --- execution knobs --------------------------------------------------
    dtype: str = "bfloat16"
    remat: str = "full"             # full | dots | none
    attn_chunk: int = 1024          # flash-style q/kv chunking
    loss_chunk: int = 512           # seq chunk for vocab-parallel CE
    ssm_chunk: int = 256            # chunked scan block
    microbatches: int = 1
    zero1: bool = True              # shard optimizer state over DP
    fsdp: bool = False              # shard bf16 params over DP too (ZeRO-3)
    grad_compress: bool = False     # int8 all-gather of param updates
    causal_tree_attn: bool = False  # binary-tree causal packing (perf opt)
    flash_vjp: bool = False         # custom-vjp flash attention (perf opt):
                                    # recompute probs in bwd instead of saving
                                    # S x S blocks as scan residuals
    moe_dispatch: str = "psum"      # psum | a2a (perf opt)
    explicit_tp: bool = False       # shard_map TP projections (perf opt):
                                    # forces bf16 activation all-reduces that
                                    # GSPMD otherwise runs on f32 accumulators

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_version == 2 else 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # parameter counting (for roofline MODEL_FLOPS = 6 N D)
    # ------------------------------------------------------------------
    def param_counts(self) -> dict:
        """Returns {'total': N, 'active': N_active} (active differs for MoE)."""
        d, L = self.d_model, self.num_layers
        emb = self.vocab_size * d
        unemb = 0 if self.tie_embeddings else self.vocab_size * d
        per_layer_total = 0
        per_layer_active = 0

        def attn_params() -> int:
            if self.attn_type == "mla":
                qp = d * self.q_lora_rank + self.q_lora_rank * self.num_heads * (
                    self.qk_nope_dim + self.qk_rope_dim)
                kvp = d * (self.kv_lora_rank + self.qk_rope_dim) + self.kv_lora_rank * (
                    self.num_heads * (self.qk_nope_dim + self.v_head_dim))
                op = self.num_heads * self.v_head_dim * d
                return qp + kvp + op
            if self.attn_type == "none":
                return 0
            q = d * self.num_heads * self.head_dim
            kv = 2 * d * self.num_kv_heads * self.head_dim
            o = self.num_heads * self.head_dim * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            mults = 3 if self.mlp_type == "swiglu" else 2
            return mults * d * ff

        def ssm_params() -> int:
            di, N = self.d_inner, self.ssm_state
            if self.ssm_version == 1:
                return (d * 2 * di            # in_proj (x, z)
                        + di * self.ssm_conv  # conv
                        + di * (self.ssm_dt_rank + 2 * N)  # x_proj
                        + self.ssm_dt_rank * di + di       # dt_proj
                        + di * N + di                      # A, D
                        + di * d)                          # out_proj
            if self.ssm_version == 2:
                nh, g = self.ssm_nheads, self.ssm_ngroups
                conv_dim = di + 2 * g * N
                return (d * (2 * di + 2 * g * N + nh)  # in_proj (z,x,B,C,dt)
                        + conv_dim * self.ssm_conv
                        + 2 * nh                        # A, D
                        + di * d)                       # out_proj
            return 0

        for i in range(L):
            p = 0
            if self.family in ("ssm",):
                p += ssm_params()
            elif self.family == "hybrid":
                p += ssm_params()
            else:
                p += attn_params()
                if self.num_experts and i >= self.num_dense_layers:
                    expert = mlp_params(self.moe_d_ff)
                    p_moe = self.num_experts * expert + d * self.num_experts
                    p_shared = self.num_shared_experts * expert
                    per_layer_total += p + p_moe + p_shared
                    per_layer_active += p + self.top_k * expert + p_shared + d * self.num_experts
                    continue
                else:
                    ff = self.dense_d_ff if (self.num_experts and i < self.num_dense_layers) else self.d_ff
                    p += mlp_params(ff)
            per_layer_total += p
            per_layer_active += p

        if self.family == "hybrid" and self.hybrid_attn_every:
            # one shared attention+mlp block (counted once; active on each use)
            shared = attn_params() + mlp_params(self.d_ff)
            per_layer_total += shared
            per_layer_active += shared * (L // self.hybrid_attn_every)

        total = emb + unemb + per_layer_total
        active = emb + unemb + per_layer_active
        return {"total": total, "active": active}


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCHS = [
    "falcon_mamba_7b",
    "internvl2_1b",
    "command_r_35b",
    "nemotron_4_340b",
    "stablelm_12b",
    "starcoder2_15b",
    "qwen3_moe_30b_a3b",
    "deepseek_v3_671b",
    "zamba2_1p2b",
    "hubert_xlarge",
]


def get_config(name: str) -> ModelConfig:
    mod_name = name.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    mod_name = name.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE_CONFIG


def supported_cells(cfg: ModelConfig):
    """The (shape) cells this architecture supports, with skip reasons."""
    out = {}
    for s in SHAPES.values():
        if s.kind == "decode" and cfg.is_encoder:
            out[s.name] = (False, "encoder-only: no decode step")
        elif s.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
            out[s.name] = (False, "pure full-attention arch: 524k decode needs "
                                  "sub-quadratic attention (skip per brief)")
        else:
            out[s.name] = (True, "")
    return out
