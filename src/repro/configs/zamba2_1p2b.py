"""zamba2-1.2b [hybrid]: 38L d_model=2048, Mamba-2 backbone (ssm_state=64) with a
shared attention(MHA 32H kv=32)+MLP(d_ff=8192) block applied periodically.
[arXiv:2411.15242; hf]

Approximation noted in DESIGN.md: the shared block is applied after every 6th
mamba layer (real Zamba2 also concatenates original embeddings and uses per-use
LoRA deltas on the shared weights; we keep a single shared block).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    mlp_type="gelu",
    norm_type="rmsnorm",
    ssm_version=2,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_ngroups=1,
    hybrid_attn_every=6,
    microbatches=2,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, ssm_state=8, ssm_head_dim=16, hybrid_attn_every=2,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
