"""deepseek-v3-671b [moe]: 61L d_model=7168 128H MLA, expert d_ff=2048
vocab=129280, MoE 1 shared + 256 routed top-8, MTP.  [arXiv:2412.19437; hf]

MLA dims follow the published config: q_lora 1536, kv_lora 512, qk_nope 128,
qk_rope 64, v_head 128; first 3 layers are dense FFN (18432).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: heads share a latent cache; kept for bookkeeping
    head_dim=128,
    d_ff=2048,                 # routed expert width
    vocab_size=129_280,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=10_000.0,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    moe_d_ff=2048,
    dense_d_ff=18_432,
    num_dense_layers=3,
    capacity_factor=1.25,
    mtp_depth=1,
    microbatches=8,
    fsdp=True,
)

SMOKE_CONFIG = CONFIG.with_(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=256,
    q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    num_experts=8, top_k=2, moe_d_ff=32, dense_d_ff=64, num_dense_layers=1,
    mtp_depth=1, attn_chunk=16, loss_chunk=16, microbatches=1,
)
