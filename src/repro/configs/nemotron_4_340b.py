"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  GQA, squared-ReLU MLP, no gated unit.  [arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    num_layers=96,
    d_model=18_432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73_728,
    vocab_size=256_000,
    mlp_type="squared_relu",
    norm_type="layernorm",
    use_bias=False,
    rope_theta=10_000.0,
    microbatches=16,          # 340B at GBS 256 needs deep accumulation
    fsdp=True,                # params ZeRO-3-sharded over DP too
)

SMOKE_CONFIG = CONFIG.with_(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=256, attn_chunk=16, loss_chunk=16, microbatches=1,
)
