"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
GQA, no-bias, parallel attn+mlp block, tied embeddings.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_528,
    vocab_size=256_000,
    mlp_type="swiglu",
    norm_type="layernorm",
    use_bias=False,
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    microbatches=8,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, attn_chunk=16, loss_chunk=16,
)
