"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free Mamba-1, vocab=65024,
ssm_state=16.  [arXiv:2410.05355; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65_024,
    attn_type="none",
    ssm_version=1,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_dt_rank=256,          # ceil(4096/16)
    tie_embeddings=True,      # falcon-mamba ties embeddings
    norm_type="rmsnorm",
    microbatches=4,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, vocab_size=128, ssm_dt_rank=4, ssm_state=4,
    attn_chunk=16, loss_chunk=16, ssm_chunk=8,
)
