"""hubert-xlarge [audio]: 48L d_model=1280 16H MHA d_ff=5120 vocab=504 (cluster
targets).  Encoder-only; conv waveform frontend is a STUB — input_specs()
provides precomputed frame embeddings.  [arXiv:2106.07447; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    mlp_type="gelu",
    norm_type="layernorm",
    use_bias=True,
    is_encoder=True,
    input_mode="embeddings",
    microbatches=2,
)

SMOKE_CONFIG = CONFIG.with_(
    microbatches=1, fsdp=False,
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=32, attn_chunk=16, loss_chunk=16,
)
