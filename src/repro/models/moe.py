"""Mixture-of-Experts layer: top-k routing, expert parallelism over the model
axis.

Baseline dispatch ("local+psum"): every TP shard holds E/tp experts; tokens
are replicated across TP.  Each shard scatters the assignments routed to its
*local* experts into a capacity-bounded (E_loc, C, d) buffer, applies the
expert FFNs as one grouped matmul, scatter-adds results back to token slots
and the shards psum-combine.  One code path serves train / prefill / decode.

Alternative dispatch ("a2a", used by the §Perf hillclimb): tokens are
sequence-sharded across TP as well; buffers exchange via all_to_all so each
token copy moves point-to-point instead of being all-reduced.  Selected with
``moe_dispatch='a2a'``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MeshCtx
from repro.compat import shard_map


def init_moe(cfg, rng):
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    s = 0.02
    p = {
        "router": (jax.random.normal(ks[0], (d, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * s).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, ff)) * s).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, ff, d)) * s).astype(dt),
    }
    if cfg.num_shared_experts:
        sf = cfg.num_shared_experts * ff
        p["ws_gate"] = (jax.random.normal(ks[4], (d, sf)) * s).astype(dt)
        p["ws_up"] = (jax.random.normal(ks[5], (d, sf)) * s).astype(dt)
        p["ws_down"] = (jax.random.normal(ks[0], (sf, d)) * s).astype(dt)
    return p


def _expert_ffn(wg, wu, wd, x):
    """x: (E_loc, C, d) grouped matmul."""
    g = jnp.einsum("ecd,edf->ecf", x, wg)
    u = jnp.einsum("ecd,edf->ecf", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_fwd(p, x, cfg, mcx: Optional[MeshCtx]):
    """x: (B,S,d) -> (B,S,d) (+aux loss stored via jax 'aux' return).

    Returns (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    tp = mcx.tp_size if mcx is not None else 1
    assert E % tp == 0
    E_loc = E // tp
    xt = x.reshape(B * S, d)
    T = B * S

    if mcx is not None and cfg.moe_dispatch == "a2a" \
            and T % (mcx.dp_size * mcx.tp_size) == 0:
        y, aux = _moe_a2a(p, xt, cfg, mcx)
        y = y.reshape(B, S, d)
        # contain the (dp x tp) token sharding to this block: back to the
        # residual stream's (dp, -, -) layout so sharding propagation never
        # pushes 256-way token sharding into the attention bwd
        y = mcx.shard(y, mcx.bspec(B), None, None)
        if "ws_gate" in p:
            g = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
            u = jnp.einsum("bsd,df->bsf", x, p["ws_up"])
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
            y = y + jnp.einsum("bsf,fd->bsd", h, p["ws_down"])
        return y, aux

    # ---- routing (computed replicated over TP; fp32) ----
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # (T,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * router_mean) * E * cfg.router_aux_coef

    C = max(1, int(math.ceil(T * k * cfg.capacity_factor / E)))

    def shard_body(xt_l, top_p_l, top_e_l, wg, wu, wd):
        """Per-device: xt (T_dp, d) [replicated over tp], experts local slice."""
        tp_idx = jax.lax.axis_index(mcx.tp) if mcx is not None else 0
        e_lo = tp_idx * E_loc
        T_l = xt_l.shape[0]
        flat_e = top_e_l.reshape(-1)                         # (T_l*k,)
        flat_p = top_p_l.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_l), k)
        local = jnp.logical_and(flat_e >= e_lo, flat_e < e_lo + E_loc)
        le = jnp.where(local, flat_e - e_lo, E_loc)          # E_loc = trash row
        # position within expert: stable rank among same-expert assignments
        order = jnp.argsort(le, stable=True)
        le_s = le[order]
        pos_s = jnp.arange(T_l * k) - jnp.searchsorted(le_s, le_s, side="left")
        pos = jnp.zeros_like(pos_s).at[order].set(pos_s)
        ok = jnp.logical_and(local, pos < C)
        slot = jnp.where(ok, le * C + pos, E_loc * C)        # overflow -> trash
        buf = jnp.zeros((E_loc * C + 1, d), xt_l.dtype)
        buf = buf.at[slot].set(jnp.where(ok[:, None], xt_l[flat_t], 0.0))
        out = _expert_ffn(wg, wu, wd, buf[:E_loc * C].reshape(E_loc, C, d))
        out = out.reshape(E_loc * C, d)
        contrib = jnp.where(ok[:, None], out[jnp.clip(slot, 0, E_loc * C - 1)], 0.0)
        y_l = jnp.zeros((T_l, d), xt_l.dtype)
        y_l = y_l.at[flat_t].add(contrib * flat_p[:, None].astype(xt_l.dtype))
        if mcx is not None:
            y_l = jax.lax.psum(y_l, mcx.tp)
        return y_l

    if mcx is not None:
        bs = mcx.bspec(T)
        y = shard_map(
            shard_body,
            mesh=mcx.mesh,
            in_specs=(P(bs, None), P(bs, None), P(bs, None),
                      P(mcx.tp, None, None), P(mcx.tp, None, None),
                      P(mcx.tp, None, None)),
            out_specs=P(bs, None),
        )(xt, top_p, top_e, p["w_gate"], p["w_up"], p["w_down"])
    else:
        y = shard_body(xt, top_p, top_e, p["w_gate"], p["w_up"], p["w_down"])

    y = y.reshape(B, S, d)
    if "ws_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["ws_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["ws_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = y + jnp.einsum("bsf,fd->bsd", h, p["ws_down"])
    return y, aux


def _moe_a2a(p, xt, cfg, mcx: MeshCtx):
    """all_to_all expert-parallel dispatch (perf opt, cfg.moe_dispatch='a2a').

    The whole layer (routing included) runs in one shard_map with tokens
    sharded over DP *and* TP — no replicated routing work and no GSPMD
    guessing around the boundary.  Each shard packs a (tp, E_loc*C, d) send
    buffer addressed by expert-owner shard; all_to_all over TP exchanges
    token payloads point-to-point; expert shards run one grouped matmul; a
    second all_to_all returns outputs to the token owners — replacing the
    (T_dp, d) psum-combine of the baseline path.  Returns (y, aux)."""
    E, k = cfg.num_experts, cfg.top_k
    tp = mcx.tp_size
    E_loc = E // tp
    T, d = xt.shape
    shards = mcx.dp + (mcx.tp,)
    T_loc = T // (mcx.dp_size * tp)
    # per (source shard, expert) capacity
    C = max(1, int(math.ceil(T_loc * k * cfg.capacity_factor / E)))
    xt = mcx.shard(xt, shards, None)

    def body(xt_l, router, wg, wu, wd):
        # ---- local routing (fp32) + aux loss via psum-mean ----
        logits = jnp.einsum("td,de->te", xt_l.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p_l, top_e_l = jax.lax.top_k(probs, k)
        top_p_l = top_p_l / jnp.sum(top_p_l, axis=-1, keepdims=True)
        nsh = mcx.dp_size * tp
        density = jax.lax.pmean(jnp.mean(
            jax.nn.one_hot(top_e_l[:, 0], E), axis=0), shards)
        router_mean = jax.lax.pmean(jnp.mean(probs, axis=0), shards)
        aux = jnp.sum(density * router_mean) * E * cfg.router_aux_coef

        flat_e = top_e_l.reshape(-1)                   # (T_loc*k,)
        flat_p = top_p_l.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(T_loc), k)
        # slot within the send buffer: experts grouped by owner shard
        order = jnp.argsort(flat_e, stable=True)
        e_s = flat_e[order]
        pos = jnp.arange(T_loc * k) - jnp.searchsorted(e_s, e_s, side="left")
        ok = pos < C
        slot = jnp.where(ok, e_s * C + pos, E * C)
        send = jnp.zeros((E * C + 1, d), xt_l.dtype)
        send = send.at[slot].set(
            jnp.where(ok[:, None], xt_l[flat_t[order]], 0.0), mode="drop")
        send = send[:E * C].reshape(tp, E_loc * C, d)
        recv = jax.lax.all_to_all(send, mcx.tp, split_axis=0, concat_axis=0,
                                  tiled=True)            # (tp, E_loc*C, d)
        # group by local expert: (tp, E_loc, C, d) -> (E_loc, tp*C, d)
        recv = recv.reshape(tp, E_loc, C, d).transpose(1, 0, 2, 3) \
            .reshape(E_loc, tp * C, d)
        out = _expert_ffn(wg, wu, wd, recv)
        out = out.reshape(E_loc, tp, C, d).transpose(1, 0, 2, 3) \
            .reshape(tp, E_loc * C, d)
        back = jax.lax.all_to_all(out, mcx.tp, split_axis=0, concat_axis=0,
                                  tiled=True)            # (tp, E_loc*C, d)
        back = back.reshape(E * C, d)
        gathered = jnp.where(ok[:, None],
                             back[jnp.clip(slot, 0, E * C - 1)], 0.0)
        y_l = jnp.zeros((T_loc, d), xt_l.dtype)
        y_l = y_l.at[flat_t[order]].add(
            gathered * flat_p[order][:, None].astype(xt_l.dtype))
        return y_l, aux

    y, aux = shard_map(
        body, mesh=mcx.mesh,
        in_specs=(P(shards, None), P(None, None),
                  P(mcx.tp, None, None), P(mcx.tp, None, None),
                  P(mcx.tp, None, None)),
        out_specs=(P(shards, None), P()),
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
