"""State-space model blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

TPU adaptation: both use *chunked* scans — within a chunk the recurrence is
evaluated in matmul form (MXU-friendly) or via a bounded associative scan;
chunk boundary states are carried by a short sequential ``lax.scan``.  The
inner dimension ``d_inner`` is sharded over the model (TP) axis; every op here
is elementwise or contracting over ``d_inner``/state, so no collectives are
needed inside a block (in/out projections are column/row-parallel).

Decode carries ``(conv_state, ssm_state)`` per layer.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import MeshCtx


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_mamba1(cfg, rng):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    R, K = cfg.ssm_dt_rank, cfg.ssm_conv
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 6)
    s = 0.02
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
    return {
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (K, di)) * s).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": (jax.random.normal(ks[2], (di, R + 2 * N)) * s).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (R, di)) * s).astype(dt),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[4], (di, d)) * s).astype(dt),
    }


def init_mamba2(cfg, rng):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    K, nh, g = cfg.ssm_conv, cfg.ssm_nheads, cfg.ssm_ngroups
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    s = 0.02
    d_in_proj = 2 * di + 2 * g * N + nh
    conv_dim = di + 2 * g * N
    return {
        "in_proj": (jax.random.normal(ks[0], (d, d_in_proj)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (K, conv_dim)) * s).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), jnp.float32),   # gated RMSNorm
        "out_proj": (jax.random.normal(ks[2], (di, d)) * s).astype(dt),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------
def causal_conv1d(x, w, b, state=None):
    """x: (B,S,C); w: (K,C).  Returns (y, tail) where tail is the last (K-1)
    inputs (for decode).  If ``state`` (B,K-1,C) given, it is prepended."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + S, :] * w[i] for i in range(K))
    tail = xp[:, -(K - 1):, :] if K > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y + b, tail


def conv1d_step(x, w, b, state):
    """x: (B,C) one step; state: (B,K-1,C)."""
    K = w.shape[0]
    xp = jnp.concatenate([state, x[:, None]], axis=1)      # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", xp, w) + b
    return y, xp[:, 1:]


# ---------------------------------------------------------------------------
# chunked diagonal selective scan (mamba1)
#   h_t = a_t * h_{t-1} + u_t ;   a, u: (B, S, C, N)
# ---------------------------------------------------------------------------
def chunked_diag_scan(a, u, chunk: int, h0=None):
    B, S, C, N = a.shape
    c = min(chunk, S)
    S_real = S
    if S % c:
        pad = c - S % c
        # identity padding: decay 1, input 0 — state passes through unchanged
        a = jnp.concatenate([a, jnp.ones((B, pad, C, N), a.dtype)], axis=1)
        u = jnp.concatenate([u, jnp.zeros((B, pad, C, N), u.dtype)], axis=1)
        S = S + pad
    nc = S // c
    a_c = a.reshape(B, nc, c, C, N)
    u_c = u.reshape(B, nc, c, C, N)

    def combine(l, r):
        al, ul = l
        ar, ur = r
        return al * ar, ul * ar + ur

    # within-chunk prefix: h_t = A_cum * h_in + U_cum
    A_cum, U_cum = jax.lax.associative_scan(
        combine, (a_c, u_c), axis=2)

    def boundary(h, xs):
        A_last, U_last = xs                                 # (B,C,N)
        h_next = A_last * h + U_last
        return h_next, h

    if h0 is None:
        h0 = jnp.zeros((B, C, N), a.dtype)
    _, h_ins = jax.lax.scan(
        boundary, h0,
        (jnp.moveaxis(A_cum[:, :, -1], 1, 0), jnp.moveaxis(U_cum[:, :, -1], 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                       # (B,nc,C,N)
    h_all = A_cum * h_ins[:, :, None] + U_cum               # (B,nc,c,C,N)
    return h_all.reshape(B, S, C, N)[:, :S_real]


def mamba1_fwd(p, x, cfg, mcx: Optional[MeshCtx], state=None):
    """x: (B,S,d) -> (B,S,d).  state=(conv_state, h) enables streaming."""
    B, S, d = x.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    if mcx is not None:
        xz = mcx.shard(xz, mcx.dp, None, mcx.tp)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    xin, conv_tail = causal_conv1d(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)

    proj = jnp.einsum("bsc,ce->bse", xin, p["x_proj"])
    dt_r, Bmat, Cmat = jnp.split(proj, [R, R + N], axis=-1)
    dt = jnp.einsum("bsr,rc->bsc", dt_r, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])                 # (B,S,di)
    A = -jnp.exp(p["A_log"])                                # (di,N)
    a = jnp.exp(dt[..., None] * A)                          # (B,S,di,N)
    u = (dt[..., None] * Bmat[:, :, None, :].astype(jnp.float32)
         * xin[..., None].astype(jnp.float32))              # (B,S,di,N)
    h0 = state[1] if state is not None else None
    h = chunked_diag_scan(a, u, cfg.ssm_chunk, h0)          # (B,S,di,N)
    y = jnp.einsum("bscn,bsn->bsc", h, Cmat.astype(jnp.float32))
    y = y + p["D"] * xin.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"])
    if state is not None:
        return out, (conv_tail, h[:, -1])
    return out


def mamba1_step(p, x, cfg, state):
    """Single decode step.  x: (B,d); state=(conv_state (B,K-1,di), h (B,di,N))."""
    conv_state, h = state
    N, R = cfg.ssm_state, cfg.ssm_dt_rank
    xz = jnp.einsum("bd,de->be", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xin, conv_state = conv1d_step(xin, p["conv_w"], p["conv_b"], conv_state)
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("bc,ce->be", xin, p["x_proj"])
    dt_r, Bv, Cv = jnp.split(proj, [R, R + N], axis=-1)
    dt = jnp.einsum("br,rc->bc", dt_r, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A)                          # (B,di,N)
    u = dt[..., None] * Bv[:, None, :].astype(jnp.float32) * \
        xin[..., None].astype(jnp.float32)
    h = a * h + u
    y = jnp.einsum("bcn,bn->bc", h, Cv.astype(jnp.float32))
    y = y + p["D"] * xin.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bc,cd->bd", y.astype(x.dtype), p["out_proj"])
    return out, (conv_state, h)


# ---------------------------------------------------------------------------
# Mamba-2 / SSD (chunked, matmul form)
# ---------------------------------------------------------------------------
def _segsum(log_a):
    """log_a: (..., c).  Returns (..., c, c) with L[i,j] = sum_{j<k<=i} log_a[k]
    for j<=i else -inf."""
    c = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]              # sum_{j<k<=i}
    mask = jnp.arange(c)[:, None] >= jnp.arange(c)[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, log_a, Bm, Cm, chunk: int, h0=None):
    """SSD scan.  xh: (B,S,nh,hd); log_a: (B,S,nh); Bm,Cm: (B,S,g,N).
    Returns y (B,S,nh,hd) and final state (B,nh,hd,N)."""
    B, S, nh, hd = xh.shape
    g, N = Bm.shape[2], Bm.shape[3]
    rep = nh // g
    c = min(chunk, S)
    S_real = S
    if S % c:
        pad = c - S % c
        xh = jnp.concatenate([xh, jnp.zeros((B, pad, nh, hd), xh.dtype)], 1)
        log_a = jnp.concatenate(
            [log_a, jnp.zeros((B, pad, nh), log_a.dtype)], 1)
        Bm = jnp.concatenate([Bm, jnp.zeros((B, pad, g, N), Bm.dtype)], 1)
        Cm = jnp.concatenate([Cm, jnp.zeros((B, pad, g, N), Cm.dtype)], 1)
        S = S + pad
    nc = S // c
    xc = xh.reshape(B, nc, c, nh, hd)
    la = log_a.reshape(B, nc, c, nh)
    Bc = jnp.repeat(Bm.reshape(B, nc, c, g, N), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(B, nc, c, g, N), rep, axis=3)

    # --- intra-chunk (quadratic in c, matmul form) ---
    Lmat = jnp.exp(_segsum(jnp.moveaxis(la, -1, 2)))        # (B,nc,nh,c,c)
    scores = jnp.einsum("bzchn,bzshn->bzhcs", Cc, Bc,
                        preferred_element_type=jnp.float32)
    scores = scores * Lmat
    y_intra = jnp.einsum("bzhcs,bzshd->bzchd", scores.astype(xh.dtype), xc,
                         preferred_element_type=jnp.float32)

    # --- chunk states: S_z = sum_j decay(end..j) B_j x_j^T ---
    cum = jnp.cumsum(la, axis=2)                            # (B,nc,c,nh)
    total = cum[:, :, -1:]
    decay_to_end = jnp.exp(total - cum)                     # (B,nc,c,nh)
    Bx = jnp.einsum("bzshn,bzshd,bzsh->bzhdn", Bc, xc, decay_to_end.astype(xh.dtype),
                    preferred_element_type=jnp.float32)     # (B,nc,nh,hd,N)

    # --- inter-chunk recurrence over chunk boundaries ---
    A_chunk = jnp.exp(total[:, :, 0])                       # (B,nc,nh)

    def boundary(h, xs):
        a_z, s_z = xs                                       # (B,nh),(B,nh,hd,N)
        h_next = a_z[..., None, None] * h + s_z
        return h_next, h

    if h0 is None:
        h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    h_fin, h_ins = jax.lax.scan(
        boundary, h0.astype(jnp.float32),
        (jnp.moveaxis(A_chunk, 1, 0), jnp.moveaxis(Bx.astype(jnp.float32), 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)                       # (B,nc,nh,hd,N)

    # --- inter-chunk contribution to outputs ---
    decay_from_start = jnp.exp(cum)                         # (B,nc,c,nh)
    y_inter = jnp.einsum("bzchn,bzndn,bzch->bzchd", Cc,
                         h_ins.astype(xh.dtype),
                         decay_from_start.astype(xh.dtype),
                         preferred_element_type=jnp.float32)
    y = (y_intra + y_inter).reshape(B, S, nh, hd)[:, :S_real]
    return y.astype(xh.dtype), h_fin


def mamba2_fwd(p, x, cfg, mcx: Optional[MeshCtx], state=None):
    """x: (B,S,d)."""
    B, S, d = x.shape
    di, N = cfg.d_inner, cfg.ssm_state
    nh, g, hd = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    if mcx is not None:
        zxbcdt = mcx.shard(zxbcdt, mcx.dp, None, mcx.tp)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * N], axis=-1)
    conv_state = state[0] if state is not None else None
    xbc, conv_tail = causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, Bm, Cm = jnp.split(xbc, [di, di + g * N], axis=-1)
    xh = xin.reshape(B, S, nh, hd)
    Bm = Bm.reshape(B, S, g, N)
    Cm = Cm.reshape(B, S, g, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                # (nh,)
    log_a = dt * A                                          # (B,S,nh)
    xdt = xh * dt[..., None].astype(xh.dtype)
    h0 = state[1] if state is not None else None
    y, h_fin = ssd_chunked(xdt, log_a, Bm, Cm, cfg.ssm_chunk, h0)
    y = y + p["D"][:, None] * xh.astype(jnp.float32).astype(y.dtype)
    y = y.reshape(B, S, di)
    # gated RMSNorm
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    out = jnp.einsum("bsc,cd->bsd", yf.astype(x.dtype), p["out_proj"])
    if state is not None:
        return out, (conv_tail, h_fin)
    return out


def mamba2_step(p, x, cfg, state):
    """Single decode step.  x: (B,d); state=(conv (B,K-1,conv_dim), h (B,nh,hd,N))."""
    conv_state, h = state
    di, N = cfg.d_inner, cfg.ssm_state
    nh, g, hd = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bd,de->be", x, p["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * N], axis=-1)
    xbc, conv_state = conv1d_step(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
    xin, Bv, Cv = jnp.split(xbc, [di, di + g * N], axis=-1)
    xhh = xin.reshape(-1, nh, hd)
    Bv = jnp.repeat(Bv.reshape(-1, g, N), nh // g, axis=1)
    Cv = jnp.repeat(Cv.reshape(-1, g, N), nh // g, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,nh)
    a = jnp.exp(dt * (-jnp.exp(p["A_log"])))                # (B,nh)
    u = jnp.einsum("bhd,bhn->bhdn", (xhh * dt[..., None].astype(xhh.dtype)
                                     ).astype(jnp.float32), Bv.astype(jnp.float32))
    h = a[..., None, None] * h + u
    y = jnp.einsum("bhdn,bhn->bhd", h, Cv.astype(jnp.float32))
    y = y + p["D"][:, None] * xhh.astype(jnp.float32)
    y = y.reshape(-1, di)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"]
    out = jnp.einsum("bc,cd->bd", yf.astype(x.dtype), p["out_proj"])
    return out, (conv_state, h)
