"""Core NN layers: norms, RoPE, MLPs, chunked (flash-style) attention, MLA,
and sequence-parallel decode attention with log-sum-exp merging.

Conventions
-----------
* params are plain dicts of jnp arrays; compute dtype is bf16, softmax/norms fp32.
* TP ("model" axis) shards attention heads in train/prefill.  Query heads are
  padded up to a multiple of the TP degree at *weight layout* time (pad head
  rows of wo are zero, so outputs are exact).
* Decode shards the KV cache over the *sequence* dimension across the model
  axis (flash-decoding style): each shard attends over its local KV chunk and
  partial results merge with a log-sum-exp psum.  This supports GQA configs
  whose kv-head count does not divide the TP degree and 500k-token caches.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map


# ---------------------------------------------------------------------------
# mesh context
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp: tuple            # data-parallel axis names, e.g. ("pod", "data")
    tp: str = "model"

    @property
    def dp_size(self) -> int:
        return int(math.prod(self.mesh.shape[a] for a in self.dp))

    @property
    def tp_size(self) -> int:
        return int(self.mesh.shape[self.tp])

    @property
    def all_axes(self) -> tuple:
        return tuple(self.dp) + (self.tp,)

    def shard(self, x, *spec):
        """Apply a sharding constraint (pjit-style)."""
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def batch_spec(self, *rest):
        return P(self.dp, *rest)

    def bspec(self, n: int):
        """DP spec entry for a batch-like dim of size n (None if indivisible,
        e.g. global_batch=1 long-context decode)."""
        return self.dp if (n % self.dp_size == 0) else None


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(p, x, cfg, eps=1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., H, D) w/ scalar positions; rotates pairs."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    # broadcast over head dim: x is (..., S, H, D); ang (..., S, d/2)
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# explicit-TP einsum wrappers (perf opt, cfg.explicit_tp)
#
# GSPMD keeps the f32 dot accumulator live across the tensor-parallel
# all-reduce when the consumer chain upcasts (norms/softmax), doubling
# activation-AR bytes.  These shard_map wrappers pin the collective to the
# declared bf16 value: column-parallel (x replicated over TP -> backward
# psums dx in bf16), row-parallel (explicit bf16 psum of partial outputs).
# ---------------------------------------------------------------------------
def tp_col_einsum(spec_eq, x, w, mcx: MeshCtx, *, w_spec, out_spec,
                  x_spec=None):
    """Column-parallel: w sharded on an output dim; x replicated over TP."""
    if mcx is None or mcx.tp_size == 1:
        return jnp.einsum(spec_eq, x, w)
    bs = mcx.bspec(x.shape[0])
    xs = x_spec if x_spec is not None else P(bs, *([None] * (x.ndim - 1)))

    def inner(x_l, w_l):
        return jnp.einsum(spec_eq, x_l, w_l)

    return shard_map(inner, mesh=mcx.mesh, in_specs=(xs, w_spec),
                         out_specs=out_spec)(x, w)


def tp_row_einsum(spec_eq, x, w, mcx: MeshCtx, *, x_spec, w_spec, out_spec):
    """Row-parallel: contraction dim sharded; explicit bf16 psum."""
    if mcx is None or mcx.tp_size == 1:
        return jnp.einsum(spec_eq, x, w)

    def inner(x_l, w_l):
        y = jnp.einsum(spec_eq, x_l, w_l)
        return jax.lax.psum(y, mcx.tp)

    return shard_map(inner, mesh=mcx.mesh, in_specs=(x_spec, w_spec),
                         out_specs=out_spec)(x, w)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_mlp(cfg, rng, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    s = 0.02
    dt = jnp.dtype(cfg.dtype)
    p = {}
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k1, (d, ff)) * s).astype(dt)
        p["w_up"] = (jax.random.normal(k2, (d, ff)) * s).astype(dt)
        p["w_down"] = (jax.random.normal(k3, (ff, d)) * s).astype(dt)
    else:
        p["w_up"] = (jax.random.normal(k1, (d, ff)) * s).astype(dt)
        p["w_down"] = (jax.random.normal(k2, (ff, d)) * s).astype(dt)
        if cfg.use_bias:
            p["b_up"] = jnp.zeros((ff,), dt)
            p["b_down"] = jnp.zeros((d,), dt)
    return p


def apply_mlp(p, x, cfg, mcx: Optional[MeshCtx] = None):
    if cfg.explicit_tp and mcx is not None and mcx.tp_size > 1 \
            and p["w_down"].shape[0] % mcx.tp_size == 0 and x.ndim == 3:
        return _apply_mlp_explicit_tp(p, x, cfg, mcx)
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        u = jnp.einsum("...d,df->...f", x, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jnp.einsum("...d,df->...f", x, p["w_up"])
        if "b_up" in p:
            h = h + p["b_up"]
        if cfg.mlp_type == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("...f,fd->...d", h, p["w_down"])
    if "b_down" in p:
        y = y + p["b_down"]
    return y


def _apply_mlp_explicit_tp(p, x, cfg, mcx: MeshCtx):
    """Whole MLP in one shard_map: column-parallel up projections, local
    activation (bias slice added locally), row-parallel down projection with
    explicit bf16 psum."""
    bs = mcx.bspec(x.shape[0])
    xs = P(bs, None, None)

    if cfg.mlp_type == "swiglu":
        ws = [p["w_gate"], p["w_up"], p["w_down"]]
        w_specs = [P(None, mcx.tp), P(None, mcx.tp), P(mcx.tp, None)]
    else:
        ws = [p["w_up"], p["w_down"]]
        w_specs = [P(None, mcx.tp), P(mcx.tp, None)]
    has_bias = "b_up" in p
    if has_bias:
        ws.append(p["b_up"])
        w_specs.append(P(mcx.tp))

    def inner(x_l, *ws_l):
        if cfg.mlp_type == "swiglu":
            wg, wu, wd = ws_l[0], ws_l[1], ws_l[2]
            g = jnp.einsum("bsd,df->bsf", x_l, wg)
            u = jnp.einsum("bsd,df->bsf", x_l, wu)
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x_l.dtype) * u
        else:
            wu, wd = ws_l[0], ws_l[1]
            h = jnp.einsum("bsd,df->bsf", x_l, wu)
            if has_bias:
                h = h + ws_l[-1]
            if cfg.mlp_type == "squared_relu":
                h = jnp.square(jax.nn.relu(h))
            else:
                h = jax.nn.gelu(h.astype(jnp.float32)).astype(x_l.dtype)
        y = jnp.einsum("bsf,fd->bsd", h, wd)
        return jax.lax.psum(y, mcx.tp)

    y = shard_map(inner, mesh=mcx.mesh,
                      in_specs=tuple([xs] + w_specs),
                      out_specs=xs)(x, *ws)
    if "b_down" in p:
        y = y + p["b_down"]
    return y


# ---------------------------------------------------------------------------
# GQA attention (train / prefill): chunked online-softmax, never S x S
# ---------------------------------------------------------------------------
def init_attention(cfg, rng, mcx: Optional[MeshCtx] = None):
    tp = mcx.tp_size if mcx is not None else 1
    H = pad_to(cfg.num_heads, tp)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 4)
    s = 0.02

    def z_pad(w, n_real, n_pad, axis):
        """zero out padded head slots"""
        if n_real == n_pad:
            return w
        idx = [slice(None)] * w.ndim
        idx[axis] = slice(n_real, n_pad)
        return w.at[tuple(idx)].set(0.0)

    p = {
        "wq": z_pad((jax.random.normal(ks[0], (d, H, hd)) * s), cfg.num_heads, H, 1).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, KV, hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, KV, hd)) * s).astype(dt),
        "wo": z_pad((jax.random.normal(ks[3], (H, hd, d)) * s), cfg.num_heads, H, 0).astype(dt),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((KV, hd), dt)
        p["bv"] = jnp.zeros((KV, hd), dt)
        p["bo"] = jnp.zeros((d,), dt)
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _qk_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool, chunk: int, mcx: Optional[MeshCtx]):
    """Chunked attention.  q: (B,S,H,D); k,v: (B,S,H,D) (kv already repeated to
    padded H).  Scans q-chunks (outer) and kv-chunks (inner, online softmax).
    Never materializes (S, S)."""
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    c = min(chunk, S)
    S_real = S
    if S % c:
        pad = c - S % c
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nq = S // c
    scale = 1.0 / math.sqrt(D)
    qc = q.reshape(B, nq, c, H, D)
    kc = k.reshape(B, nq, c, H, D)
    vc = v.reshape(B, nq, c, H, Dv)

    def q_block(qi):
        qb, q_idx = qi                                     # (B,c,H,D), ()
        q_pos = q_idx * c + jnp.arange(c)

        def kv_step(carry, kvi):
            m, l, acc = carry
            kb, vb, k_idx = kvi
            k_pos = k_idx * c + jnp.arange(c)
            s_blk = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
            mask = jnp.broadcast_to(k_pos[None, :] < S_real, (c, c))
            if causal:
                mask = jnp.logical_and(mask, q_pos[:, None] >= k_pos[None, :])
            s_blk = jnp.where(mask[None, None], s_blk, -1e30)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p_blk = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p_blk, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_blk.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, c), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, c), jnp.float32)
        a0 = jnp.zeros((B, H, c, Dv), jnp.float32)
        ks = jnp.moveaxis(kc, 1, 0)                        # (nq,B,c,H,D)
        vs = jnp.moveaxis(vc, 1, 0)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nq)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)                     # (B,c,H,D)

    qs = jnp.moveaxis(qc, 1, 0)                            # (nq,B,c,H,D)
    outs = jax.lax.map(q_block, (qs, jnp.arange(nq)))      # (nq,B,c,H,Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dv)
    return out[:, :S_real].astype(q.dtype)


# ---------------------------------------------------------------------------
# custom-VJP flash attention: the scan formulation above is memory-correct in
# the forward pass but plain autodiff saves every probs block as a scan
# residual (S x S traffic + memory in the backward).  This version saves only
# (q, k, v, out, m, l) and recomputes probs blockwise in the backward — the
# standard flash-attention backward, expressed in XLA.
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_core(q, k, v, causal: bool, chunk: int):
    out, _, _ = _flash_fwd_impl(q, k, v, causal, chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, c):
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    nq = S // c
    scale = 1.0 / math.sqrt(D)
    qc = jnp.moveaxis(q.reshape(B, nq, c, H, D), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nq, c, H, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nq, c, H, Dv), 1, 0)

    def q_block(qi):
        qb, q_idx = qi
        q_pos = q_idx * c + jnp.arange(c)

        def kv_step(carry, kvi):
            m, l, acc = carry
            kb, vb, k_idx = kvi
            k_pos = k_idx * c + jnp.arange(c)
            s_blk = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s_blk = jnp.where(mask[None, None], s_blk, -1e30)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p_blk = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p_blk, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p_blk.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, c), -1e30, jnp.float32)
        l0 = jnp.zeros((B, H, c), jnp.float32)
        a0 = jnp.zeros((B, H, c, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nq)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(o, 1, 2), m, l      # (B,c,H,Dv), (B,H,c)

    outs, ms, ls = jax.lax.map(q_block, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, Dv).astype(q.dtype)
    m = jnp.moveaxis(ms, 0, 2).reshape(B, H, S)             # (B,H,S)
    l = jnp.moveaxis(ls, 0, 2).reshape(B, H, S)
    return out, m, l


def _flash_fwd(q, k, v, causal, chunk):
    out, m, l = _flash_fwd_impl(q, k, v, causal, chunk)
    return out, (q, k, v, out, m, l)


def _flash_bwd(causal, c, res, g):
    q, k, v, out, m, l = res
    B, S, H, D = q.shape
    Dv = v.shape[-1]
    nq = S // c
    scale = 1.0 / math.sqrt(D)
    # D_i = rowsum(dO * O)  (B,H,S)
    delta = jnp.sum(g.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                                  # (B,S,H)
    delta = jnp.moveaxis(delta, 1, 2)                         # (B,H,S)
    qc = jnp.moveaxis(q.reshape(B, nq, c, H, D), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nq, c, H, D), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nq, c, H, Dv), 1, 0)
    gc = jnp.moveaxis(g.reshape(B, nq, c, H, Dv), 1, 0)

    def q_block(carry, xs):
        dk, dv = carry                                       # (nq,B,c,H,D) f32
        qb, gb, q_idx = xs
        q_pos = q_idx * c + jnp.arange(c)
        m_i = jax.lax.dynamic_slice_in_dim(m, q_idx * c, c, axis=2)
        l_i = jax.lax.dynamic_slice_in_dim(l, q_idx * c, c, axis=2)
        d_i = jax.lax.dynamic_slice_in_dim(delta, q_idx * c, c, axis=2)

        def kv_step(dq_acc, kvj):
            kb, vb, dk_j, dv_j, k_idx = kvj
            k_pos = k_idx * c + jnp.arange(c)
            s_blk = jnp.einsum("bqhd,bkhd->bhqk", qb, kb,
                               preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s_blk = jnp.where(mask[None, None], s_blk, -1e30)
            p = jnp.exp(s_blk - m_i[..., None]) / \
                jnp.maximum(l_i, 1e-30)[..., None]            # (B,H,c,c)
            dv_j = dv_j + jnp.einsum("bhqk,bqhd->bkhd", p,
                                     gb.astype(jnp.float32))
            dp = jnp.einsum("bqhd,bkhd->bhqk", gb.astype(jnp.float32),
                            vb.astype(jnp.float32))
            ds = p * (dp - d_i[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhqk,bkhd->bqhd", ds,
                                         kb.astype(jnp.float32))
            dk_j = dk_j + jnp.einsum("bhqk,bqhd->bkhd", ds,
                                     qb.astype(jnp.float32))
            return dq_acc, (dk_j, dv_j)

        dq0 = jnp.zeros((B, c, H, D), jnp.float32)
        dq_i, (dk, dv) = jax.lax.scan(
            kv_step, dq0, (kc, vc, dk, dv, jnp.arange(nq)))
        return (dk, dv), dq_i

    dk0 = jnp.zeros((nq, B, c, H, D), jnp.float32)
    dv0 = jnp.zeros((nq, B, c, H, Dv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_block, (dk0, dv0),
                                 (qc, gc, jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, H, D).astype(q.dtype)
    dk = jnp.moveaxis(dk, 0, 1).reshape(B, S, H, D).astype(k.dtype)
    dv = jnp.moveaxis(dv, 0, 1).reshape(B, S, H, Dv).astype(v.dtype)
    return dq, dk, dv


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_vjp(q, k, v, *, causal: bool, chunk: int,
                        mcx: Optional[MeshCtx]):
    """Padded wrapper around the custom-vjp flash core."""
    B, S, H, D = q.shape
    c = min(chunk, S)
    S_real = S
    if S % c:
        pad = c - S % c
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad keys at a *masked-out* position: give them q_pos > everything
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if not causal and S % c:
        # non-causal needs explicit masking of padded keys; fall back
        return flash_attention(q[:, :S_real + (c - S_real % c) % c],
                               k, v, causal=causal, chunk=chunk,
                               mcx=mcx)[:, :S_real]
    out = _flash_core(q, k, v, causal, c)
    return out[:, :S_real]


def causal_tree_attention(q, k, v, *, chunk: int, mcx: Optional[MeshCtx]):
    """Binary-tree causal packing (perf optimization, see EXPERIMENTS §Perf).

    causal(S) = causal on each half + *unmasked* dense cross-attention of the
    second half onto the first half.  Recursing log2(S/chunk) times evaluates
    the causal triangle with dense rectangles only — removing the ~2x masked-
    FLOP waste of the scan formulation.  Combination uses log-sum-exp merge.
    """
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)

    def dense_block(qb, kb, vb, causal_mask):
        # qb: (..., sq, H, D) small enough to do directly per recursion leaf
        s_blk = jnp.einsum("...qhd,...khd->...hqk", qb, kb,
                           preferred_element_type=jnp.float32) * scale
        if causal_mask:
            sq, sk = s_blk.shape[-2], s_blk.shape[-1]
            mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
            s_blk = jnp.where(mask, s_blk, -1e30)
        m = jnp.max(s_blk, axis=-1)
        p = jnp.exp(s_blk - m[..., None])
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("...hqk,...khd->...hqd", p.astype(vb.dtype), vb,
                       preferred_element_type=jnp.float32)
        return m, l, o

    def merge(a, b):
        (ma, la, oa), (mb, lb, ob) = a, b
        m = jnp.maximum(ma, mb)
        ca, cb = jnp.exp(ma - m), jnp.exp(mb - m)
        return m, la * ca + lb * cb, oa * ca[..., None] + ob * cb[..., None]

    def rec(qb, kb, vb):
        s = qb.shape[-3]
        if s <= chunk:
            return dense_block(qb, kb, vb, True)
        h = s // 2
        q1, q2 = qb[..., :h, :, :], qb[..., h:, :, :]
        k1, k2 = kb[..., :h, :, :], kb[..., h:, :, :]
        v1, v2 = vb[..., :h, :, :], vb[..., h:, :, :]
        m1, l1, o1 = rec(q1, k1, v1)
        m2a, l2a, o2a = rec(q2, k2, v2)
        m2b, l2b, o2b = dense_block(q2, k1, v1, False)     # dense rectangle
        m2, l2, o2 = merge((m2a, l2a, o2a), (m2b, l2b, o2b))
        return (jnp.concatenate([m1, m2], axis=-1),
                jnp.concatenate([l1, l2], axis=-1),
                jnp.concatenate([o1, o2], axis=-2))

    m, l, o = rec(q, k, v)                                 # o: (B,H,S,D)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return jnp.swapaxes(out, 1, 2).astype(q.dtype)         # (B,S,H,D)


def repeat_kv(x, h_out: int):
    """(B,S,KV,D) -> (B,S,h_out,D) by group repetition."""
    B, S, KV, D = x.shape
    rep = h_out // KV
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, KV, rep, D)).reshape(
        B, S, h_out, D)


def attention_fwd(p, x, cfg, mcx: MeshCtx, *, positions, causal=True,
                  return_kv=False):
    """Train/prefill attention.  x: (B,S,d)."""
    B, S, d = x.shape
    tp = mcx.tp_size
    H = pad_to(cfg.num_heads, tp)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    bs = mcx.bspec(B) if mcx is not None else None
    use_xtp = (cfg.explicit_tp and mcx is not None and mcx.tp_size > 1)
    if use_xtp:
        q = tp_col_einsum("bsd,dhk->bshk", x, p["wq"], mcx,
                          w_spec=P(None, mcx.tp, None),
                          out_spec=P(bs, None, mcx.tp, None))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    if cfg.attn_type != "nope" and cfg.rope_theta and not cfg.is_encoder:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = mcx.shard(q, mcx.dp, None, mcx.tp, None)
    kv_cache = (k, v) if return_kv else None
    k = repeat_kv(k, H)
    v = repeat_kv(v, H)
    k = mcx.shard(k, mcx.dp, None, mcx.tp, None)
    v = mcx.shard(v, mcx.dp, None, mcx.tp, None)
    if causal and cfg.causal_tree_attn:
        out = causal_tree_attention(q, k, v, chunk=cfg.attn_chunk, mcx=mcx)
    elif cfg.flash_vjp:
        out = flash_attention_vjp(q, k, v, causal=causal,
                                  chunk=cfg.attn_chunk, mcx=mcx)
    else:
        out = flash_attention(q, k, v, causal=causal, chunk=cfg.attn_chunk, mcx=mcx)
    # pin the output (and thus the bwd cotangent) to head-sharding so token
    # shardings from neighbouring blocks (e.g. a2a MoE) never propagate into
    # the attention backward
    out = mcx.shard(out, mcx.bspec(B), None, mcx.tp, None)
    if use_xtp:
        y = tp_row_einsum("bshk,hkd->bsd", out, p["wo"], mcx,
                          x_spec=P(bs, None, mcx.tp, None),
                          w_spec=P(mcx.tp, None, None),
                          out_spec=P(bs, None, None))
    else:
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    if return_kv:
        return y, kv_cache
    return y


# ---------------------------------------------------------------------------
# GQA decode attention: sequence-sharded KV cache + LSE merge over TP
# ---------------------------------------------------------------------------
def gqa_decode_attention(p, x, cache, pos, cfg, mcx: MeshCtx):
    """One-token decode.  x: (B,1,d).  cache: dict(k,v): (B,S,KV,hd), sharded
    (dp, tp, None, None) — sequence dim split over the model axis.

    Returns (y (B,1,d), new_cache).
    """
    B = x.shape[0]
    tp = mcx.tp_size
    H = pad_to(cfg.num_heads, tp)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    S = cache["k"].shape[1]
    G = H // KV

    q = jnp.einsum("bd,dhk->bhk", x[:, 0], p["wq"])
    k_new = jnp.einsum("bd,dhk->bhk", x[:, 0], p["wk"])
    v_new = jnp.einsum("bd,dhk->bhk", x[:, 0], p["wv"])
    if "bq" in p:
        q, k_new, v_new = q + p["bq"], k_new + p["bk"], v_new + p["bv"]
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"])
        k_new = _qk_norm(k_new, p["k_norm"])
    if cfg.rope_theta and not cfg.is_encoder:
        q = apply_rope(q[:, None], jnp.full((B, 1), pos), cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], jnp.full((B, 1), pos),
                           cfg.rope_theta)[:, 0]

    def inner(q_l, k_new_l, v_new_l, ck, cv):
        # local shapes: q (Bl,H,hd), cache (Bl, S_loc, KV, hd)
        S_loc = ck.shape[1]
        shard = jax.lax.axis_index(mcx.tp)
        local_idx = pos - shard * S_loc
        ok = jnp.logical_and(local_idx >= 0, local_idx < S_loc)
        li = jnp.clip(local_idx, 0, S_loc - 1)
        ck_up = jax.lax.dynamic_update_slice(
            ck, k_new_l[:, None], (0, li, 0, 0))
        cv_up = jax.lax.dynamic_update_slice(
            cv, v_new_l[:, None], (0, li, 0, 0))
        ck = jnp.where(ok, ck_up, ck)
        cv = jnp.where(ok, cv_up, cv)
        # grouped attention over local chunk
        qg = q_l.reshape(q_l.shape[0], KV, G, hd)
        s_loc = jnp.einsum("bkgd,bskd->bkgs", qg, ck,
                           preferred_element_type=jnp.float32)
        s_loc = s_loc / math.sqrt(hd)
        k_pos = shard * S_loc + jnp.arange(S_loc)
        valid = k_pos <= pos
        s_loc = jnp.where(valid[None, None, None, :], s_loc, -1e30)
        m_loc = jnp.max(s_loc, axis=-1)
        p_loc = jnp.exp(s_loc - m_loc[..., None])
        l_loc = jnp.sum(p_loc, axis=-1)
        o_loc = jnp.einsum("bkgs,bskd->bkgd", p_loc.astype(cv.dtype), cv,
                           preferred_element_type=jnp.float32)
        # log-sum-exp merge across the model axis
        m_g = jax.lax.pmax(m_loc, mcx.tp)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, mcx.tp)
        o_g = jax.lax.psum(o_loc * corr[..., None], mcx.tp)
        out = (o_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(q_l.dtype)
        return out.reshape(q_l.shape[0], KV * G, hd), ck, cv

    bs = mcx.bspec(B)
    out, ck, cv = shard_map(
        inner,
        mesh=mcx.mesh,
        in_specs=(P(bs, None, None), P(bs, None, None),
                  P(bs, None, None),
                  P(bs, mcx.tp, None, None), P(bs, mcx.tp, None, None)),
        out_specs=(P(bs, None, None),
                   P(bs, mcx.tp, None, None), P(bs, mcx.tp, None, None)),
    )(q, k_new, v_new, cache["k"], cache["v"])

    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y[:, None], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (deepseek-v3)
# ---------------------------------------------------------------------------
def init_mla(cfg, rng, mcx: Optional[MeshCtx] = None):
    d = cfg.d_model
    H = cfg.num_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 8)
    s = 0.02
    return {
        "wq_a": (jax.random.normal(ks[0], (d, qr)) * s).astype(dt),
        "q_a_norm": jnp.ones((qr,), jnp.float32),
        "wq_b": (jax.random.normal(ks[1], (qr, H, dn + dr)) * s).astype(dt),
        "wkv_a": (jax.random.normal(ks[2], (d, kvr + dr)) * s).astype(dt),
        "kv_a_norm": jnp.ones((kvr,), jnp.float32),
        "wk_b": (jax.random.normal(ks[3], (kvr, H, dn)) * s).astype(dt),
        "wv_b": (jax.random.normal(ks[4], (kvr, H, dv)) * s).astype(dt),
        "wo": (jax.random.normal(ks[5], (H, dv, d)) * s).astype(dt),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def mla_fwd(p, x, cfg, mcx: MeshCtx, *, positions, return_kv=False):
    """MLA train/prefill: non-absorbed (matmul-friendly) path."""
    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    bs = mcx.bspec(B) if mcx is not None else None
    use_xtp = (cfg.explicit_tp and mcx is not None and mcx.tp_size > 1
               and H % mcx.tp_size == 0)

    def col(eq, xx, w):
        if use_xtp:
            return tp_col_einsum(eq, xx, w, mcx,
                                 w_spec=P(None, mcx.tp, None),
                                 out_spec=P(bs, None, mcx.tp, None))
        return jnp.einsum(eq, xx, w)

    q_lat = _rms(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_a_norm"])
    q = col("bsr,rhk->bshk", q_lat, p["wq_b"])             # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])        # (B,S,kvr+dr)
    c_kv = _rms(kv_a[..., :kvr], p["kv_a_norm"])
    k_rope = apply_rope(kv_a[..., None, kvr:], positions, cfg.rope_theta)

    k_nope = col("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = col("bsr,rhk->bshk", c_kv, p["wv_b"])
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))], axis=-1)
    q_full = mcx.shard(q_full, mcx.dp, None, mcx.tp, None)
    k_full = mcx.shard(k_full, mcx.dp, None, mcx.tp, None)
    v = mcx.shard(v, mcx.dp, None, mcx.tp, None)
    if cfg.flash_vjp:
        out = flash_attention_vjp(q_full, k_full, v, causal=True,
                                  chunk=cfg.attn_chunk, mcx=mcx)
    else:
        out = flash_attention(q_full, k_full, v, causal=True,
                              chunk=cfg.attn_chunk, mcx=mcx)
    if use_xtp:
        y = tp_row_einsum("bshk,hkd->bsd", out, p["wo"], mcx,
                          x_spec=P(bs, None, mcx.tp, None),
                          w_spec=P(mcx.tp, None, None),
                          out_spec=P(bs, None, None))
    else:
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (c_kv, k_rope[:, :, 0, :])
    return y


def mla_decode_attention(p, x, cache, pos, cfg, mcx: MeshCtx):
    """Absorbed MLA decode: scores/context computed in the 512-d latent space.
    cache: {"c_kv": (B,S,kvr), "k_rope": (B,S,dr)}, seq-sharded over TP."""
    B = x.shape[0]
    H = cfg.num_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank

    q_lat = _rms(jnp.einsum("bd,dr->br", x[:, 0], p["wq_a"]), p["q_a_norm"])
    q = jnp.einsum("br,rhk->bhk", q_lat, p["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope[:, None], jnp.full((B, 1), pos),
                        cfg.rope_theta)[:, 0]
    # absorb: q_nope (B,H,dn) @ wk_b (kvr,H,dn) -> (B,H,kvr)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope, p["wk_b"])

    kv_a = jnp.einsum("bd,dr->br", x[:, 0], p["wkv_a"])
    c_new = _rms(kv_a[..., :kvr], p["kv_a_norm"])
    kr_new = apply_rope(kv_a[:, None, None, kvr:], jnp.full((B, 1), pos),
                        cfg.rope_theta)[:, 0, 0]

    def inner(q_abs_l, q_rope_l, c_new_l, kr_new_l, cc, ckr):
        S_loc = cc.shape[1]
        shard = jax.lax.axis_index(mcx.tp)
        local_idx = pos - shard * S_loc
        ok = jnp.logical_and(local_idx >= 0, local_idx < S_loc)
        li = jnp.clip(local_idx, 0, S_loc - 1)
        cc = jnp.where(ok, jax.lax.dynamic_update_slice(
            cc, c_new_l[:, None], (0, li, 0)), cc)
        ckr = jnp.where(ok, jax.lax.dynamic_update_slice(
            ckr, kr_new_l[:, None], (0, li, 0)), ckr)
        s_loc = (jnp.einsum("bhr,bsr->bhs", q_abs_l, cc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bhk,bsk->bhs", q_rope_l, ckr,
                              preferred_element_type=jnp.float32))
        s_loc = s_loc / math.sqrt(dn + dr)
        k_pos = shard * S_loc + jnp.arange(S_loc)
        s_loc = jnp.where((k_pos <= pos)[None, None, :], s_loc, -1e30)
        m_loc = jnp.max(s_loc, axis=-1)
        p_loc = jnp.exp(s_loc - m_loc[..., None])
        l_loc = jnp.sum(p_loc, axis=-1)
        ctx_loc = jnp.einsum("bhs,bsr->bhr", p_loc.astype(cc.dtype), cc,
                             preferred_element_type=jnp.float32)
        m_g = jax.lax.pmax(m_loc, mcx.tp)
        corr = jnp.exp(m_loc - m_g)
        l_g = jax.lax.psum(l_loc * corr, mcx.tp)
        ctx_g = jax.lax.psum(ctx_loc * corr[..., None], mcx.tp)
        ctx = (ctx_g / jnp.maximum(l_g, 1e-30)[..., None])
        return ctx.astype(q_abs_l.dtype), cc, ckr

    bs = mcx.bspec(B)
    ctx, cc, ckr = shard_map(
        inner,
        mesh=mcx.mesh,
        in_specs=(P(bs, None, None), P(bs, None, None),
                  P(bs, None), P(bs, None),
                  P(bs, mcx.tp, None), P(bs, mcx.tp, None)),
        out_specs=(P(bs, None, None),
                   P(bs, mcx.tp, None), P(bs, mcx.tp, None)),
    )(q_abs, q_rope, c_new, kr_new, cache["c_kv"], cache["k_rope"])

    # un-absorb: ctx (B,H,kvr) @ wv_b (kvr,H,dv) -> (B,H,dv)
    out = jnp.einsum("bhr,rhk->bhk", ctx, p["wv_b"])
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])
    return y[:, None], {"c_kv": cc, "k_rope": ckr}
