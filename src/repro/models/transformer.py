"""Model stacks: decoder LMs, encoder-only, MoE, SSM and hybrid variants.

Layer stacks are ``lax.scan`` over parameter pytrees stacked on a leading
layer axis (compile-time and HLO-size friendly), with ``jax.checkpoint``
(remat) applied to the layer body.  Prefill/decode thread KV / SSM caches
through the scan.  Hybrid (zamba2) keeps a *shared* attention+MLP block whose
per-application KV caches live in a compact (n_attn_slots, ...) carry.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import MeshCtx


# ---------------------------------------------------------------------------
# per-layer init / fwd
# ---------------------------------------------------------------------------
def init_layer(cfg, rng, mcx, kind: str):
    """kind: dense | moe | moe_dense | ssm | hybrid"""
    ks = jax.random.split(rng, 4)
    p = {}
    if kind in ("dense", "moe", "moe_dense"):
        p["ln_attn"] = L.init_norm(cfg)
        if cfg.attn_type == "mla":
            p["attn"] = L.init_mla(cfg, ks[0], mcx)
        else:
            p["attn"] = L.init_attention(cfg, ks[0], mcx)
        if not cfg.parallel_block:
            p["ln_mlp"] = L.init_norm(cfg)
        if kind == "moe":
            p["moe"] = MOE.init_moe(cfg, ks[1])
        elif kind == "moe_dense":
            p["mlp"] = L.init_mlp(cfg, ks[1], cfg.dense_d_ff)
        else:
            p["mlp"] = L.init_mlp(cfg, ks[1])
    elif kind == "ssm":
        p["ln"] = L.init_norm(cfg)
        p["ssm"] = SSM.init_mamba1(cfg, ks[0])
    elif kind == "hybrid":
        p["ln"] = L.init_norm(cfg)
        p["ssm"] = SSM.init_mamba2(cfg, ks[0])
    return p


def init_shared_block(cfg, rng, mcx):
    ks = jax.random.split(rng, 2)
    return {
        "ln_attn": L.init_norm(cfg),
        "attn": L.init_attention(cfg, ks[0], mcx),
        "ln_mlp": L.init_norm(cfg),
        "mlp": L.init_mlp(cfg, ks[1]),
    }


def attn_block_fwd(p, x, cfg, mcx, positions, *, causal, return_kv=False):
    h = L.apply_norm(p["ln_attn"], x, cfg)
    if cfg.attn_type == "mla":
        out = L.mla_fwd(p["attn"], h, cfg, mcx, positions=positions,
                        return_kv=return_kv)
    else:
        out = L.attention_fwd(p["attn"], h, cfg, mcx, positions=positions,
                              causal=causal, return_kv=return_kv)
    if return_kv:
        attn_y, kv = out
    else:
        attn_y, kv = out, None

    if cfg.parallel_block:
        # cohere-style: one shared input norm, attn+mlp in parallel
        y = x + attn_y + L.apply_mlp(p["mlp"], h, cfg, mcx)
        return (y, kv) if return_kv else y

    x = x + attn_y
    h2 = L.apply_norm(p["ln_mlp"], x, cfg)
    if "moe" in p:
        mlp_y, aux = MOE.moe_fwd(p["moe"], h2, cfg, mcx)
    else:
        mlp_y, aux = L.apply_mlp(p["mlp"], h2, cfg, mcx), 0.0
    y = x + mlp_y
    if return_kv:
        return (y, aux, kv)
    return y, aux


def attn_block_decode(p, x, cache, pos, cfg, mcx):
    h = L.apply_norm(p["ln_attn"], x, cfg)
    if cfg.attn_type == "mla":
        attn_y, cache = L.mla_decode_attention(p["attn"], h, cache, pos, cfg, mcx)
    else:
        attn_y, cache = L.gqa_decode_attention(p["attn"], h, cache, pos, cfg, mcx)
    if cfg.parallel_block:
        return x + attn_y + L.apply_mlp(p["mlp"], h, cfg, mcx), cache
    x = x + attn_y
    h2 = L.apply_norm(p["ln_mlp"], x, cfg)
    if "moe" in p:
        mlp_y, _ = MOE.moe_fwd(p["moe"], h2, cfg, mcx)
    else:
        mlp_y = L.apply_mlp(p["mlp"], h2, cfg, mcx)
    return x + mlp_y, cache


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------
def _layer_kinds(cfg):
    if cfg.family in ("dense", "vlm", "audio"):
        return ["dense"] * cfg.num_layers
    if cfg.family == "moe":
        return (["moe_dense"] * cfg.num_dense_layers
                + ["moe"] * (cfg.num_layers - cfg.num_dense_layers))
    if cfg.family == "ssm":
        return ["ssm"] * cfg.num_layers
    if cfg.family == "hybrid":
        return ["hybrid"] * cfg.num_layers
    raise ValueError(cfg.family)


def hybrid_attn_slots(cfg):
    """Layer indices after which the shared block applies, and their slots."""
    idxs = [i for i in range(cfg.num_layers)
            if (i + 1) % cfg.hybrid_attn_every == 0]
    return idxs


def init_stack(cfg, rng, mcx):
    kinds = _layer_kinds(cfg)
    ks = jax.random.split(rng, 8)
    dt = jnp.dtype(cfg.dtype)
    V = L.pad_to(cfg.vocab_size, 256)      # Megatron-style vocab padding
    params = {}
    params["emb"] = (jax.random.normal(ks[0], (V, cfg.d_model))
                     * 0.02).astype(dt)
    if not cfg.tie_embeddings:
        params["unemb"] = (jax.random.normal(
            ks[1], (cfg.d_model, V)) * 0.02).astype(dt)
    params["ln_final"] = L.init_norm(cfg)

    # group contiguous identical kinds into scanned stacks
    groups = []
    start = 0
    for i in range(1, len(kinds) + 1):
        if i == len(kinds) or kinds[i] != kinds[start]:
            groups.append((kinds[start], start, i))
            start = i
    stacks = []
    rlayers = jax.random.split(ks[2], len(kinds))
    for kind, lo, hi in groups:
        rs = jnp.stack([rlayers[i] for i in range(lo, hi)])
        stacked = jax.vmap(lambda r: init_layer(cfg, r, mcx, kind))(rs)
        stacks.append(stacked)
    params["stacks"] = stacks

    if cfg.family == "hybrid":
        params["shared"] = init_shared_block(cfg, ks[3], mcx)
    if cfg.mtp_depth:
        params["mtp"] = {
            "proj": (jax.random.normal(ks[4], (2 * cfg.d_model, cfg.d_model))
                     * 0.02).astype(dt),
            "ln_h": L.init_norm(cfg),
            "ln_e": L.init_norm(cfg),
            "layer": init_layer(cfg, ks[5], mcx,
                                "moe" if cfg.family == "moe" else "dense"),
        }
    return params


def stack_groups(cfg):
    kinds = _layer_kinds(cfg)
    groups = []
    start = 0
    for i in range(1, len(kinds) + 1):
        if i == len(kinds) or kinds[i] != kinds[start]:
            groups.append((kinds[start], start, i))
            start = i
    return groups


def _maybe_remat(f, cfg):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f)


# ---------------------------------------------------------------------------
# training / encoder forward
# ---------------------------------------------------------------------------
def forward_train(params, x, cfg, mcx: MeshCtx, positions):
    """x: hidden after embedding (B,S,d).  Returns (hidden, aux_loss)."""
    causal = not cfg.is_encoder
    aux_total = 0.0
    groups = stack_groups(cfg)

    if cfg.family == "hybrid":
        slots = hybrid_attn_slots(cfg)
        apply_flags = jnp.zeros((cfg.num_layers,), jnp.bool_).at[
            jnp.array(slots)].set(True)

        def body(carry, xs):
            h = carry
            lp, flag = xs
            hn = L.apply_norm(lp["ln"], h, cfg)
            h = h + SSM.mamba2_fwd(lp["ssm"], hn, cfg, mcx)

            def with_attn(h):
                y, _ = attn_block_fwd(params["shared"], h, cfg, mcx,
                                      positions, causal=causal)
                return y
            h = jax.lax.cond(flag, with_attn, lambda h: h, h)
            return h, None

        body = _maybe_remat(body, cfg)
        x, _ = jax.lax.scan(body, x, (params["stacks"][0], apply_flags))
        return x, aux_total

    for (kind, lo, hi), stacked in zip(groups, params["stacks"]):
        if kind == "ssm":
            def body(h, lp):
                hn = L.apply_norm(lp["ln"], h, cfg)
                return h + SSM.mamba1_fwd(lp["ssm"], hn, cfg, mcx), None
            body = _maybe_remat(body, cfg)
            x, _ = jax.lax.scan(body, x, stacked)
        else:
            def body(carry, lp):
                h, aux = carry
                if cfg.parallel_block:
                    y = attn_block_fwd(lp, h, cfg, mcx, positions,
                                       causal=causal)
                    da = 0.0
                else:
                    y, da = attn_block_fwd(lp, h, cfg, mcx, positions,
                                           causal=causal)
                return (y, aux + da), None
            body = _maybe_remat(body, cfg)
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
    return x, aux_total


# ---------------------------------------------------------------------------
# prefill: forward + emit caches
# ---------------------------------------------------------------------------
def forward_prefill(params, x, cfg, mcx: MeshCtx, positions):
    """Returns (hidden, caches).  caches layout depends on family."""
    causal = not cfg.is_encoder
    groups = stack_groups(cfg)

    if cfg.family == "ssm":
        def body(h, lp):
            hn = L.apply_norm(lp["ln"], h, cfg)
            B = h.shape[0]
            K = cfg.ssm_conv
            zero_state = (jnp.zeros((B, K - 1, cfg.d_inner), h.dtype),
                          jnp.zeros((B, cfg.d_inner, cfg.ssm_state),
                                    jnp.float32))
            y, st = SSM.mamba1_fwd(lp["ssm"], hn, cfg, mcx, state=zero_state)
            return h + y, st
        x, caches = jax.lax.scan(body, x, params["stacks"][0])
        return x, {"ssm": caches}

    if cfg.family == "hybrid":
        slots = hybrid_attn_slots(cfg)
        n_slots = len(slots)
        apply_flags = jnp.zeros((cfg.num_layers,), jnp.bool_).at[
            jnp.array(slots)].set(True)
        slot_idx = jnp.zeros((cfg.num_layers,), jnp.int32)
        for si, li in enumerate(slots):
            slot_idx = slot_idx.at[li].set(si)
        B, S = x.shape[0], x.shape[1]
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        kc0 = jnp.zeros((n_slots, B, S, KV, hd), x.dtype)
        vc0 = jnp.zeros((n_slots, B, S, KV, hd), x.dtype)
        kc0 = mcx.shard(kc0, None, mcx.dp, mcx.tp, None, None)
        vc0 = mcx.shard(vc0, None, mcx.dp, mcx.tp, None, None)

        def body(carry, xs):
            h, kc, vc = carry
            lp, flag, si = xs
            hn = L.apply_norm(lp["ln"], h, cfg)
            B = h.shape[0]
            K = cfg.ssm_conv
            conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
            zero_state = (jnp.zeros((B, K - 1, conv_dim), h.dtype),
                          jnp.zeros((B, cfg.ssm_nheads, cfg.ssm_head_dim,
                                     cfg.ssm_state), jnp.float32))
            y, st = SSM.mamba2_fwd(lp["ssm"], hn, cfg, mcx, state=zero_state)
            h = h + y

            def with_attn(op):
                h, kc, vc = op
                y, _, kv = attn_block_fwd(params["shared"], h, cfg, mcx,
                                          positions, causal=True,
                                          return_kv=True)
                k_new, v_new = kv
                kc = jax.lax.dynamic_update_slice(
                    kc, k_new[None].astype(kc.dtype), (si, 0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    vc, v_new[None].astype(vc.dtype), (si, 0, 0, 0, 0))
                return y, kc, vc

            h, kc, vc = jax.lax.cond(flag, with_attn, lambda op: op,
                                     (h, kc, vc))
            return (h, kc, vc), st

        (x, kc, vc), ssm_states = jax.lax.scan(
            body, (x, kc0, vc0), (params["stacks"][0], apply_flags, slot_idx))
        return x, {"ssm": ssm_states, "k": kc, "v": vc}

    # attention families (dense / vlm / moe / audio)
    caches_k, caches_v, caches_ckv, caches_kr = [], [], [], []
    for (kind, lo, hi), stacked in zip(groups, params["stacks"]):
        def body(h, lp):
            if cfg.parallel_block:
                y, kv = attn_block_fwd(lp, h, cfg, mcx, positions,
                                       causal=causal, return_kv=True)
            else:
                y, _, kv = attn_block_fwd(lp, h, cfg, mcx, positions,
                                          causal=causal, return_kv=True)
            return y, kv
        x, kv = jax.lax.scan(body, x, stacked)
        if cfg.attn_type == "mla":
            caches_ckv.append(kv[0])
            caches_kr.append(kv[1])
        else:
            caches_k.append(kv[0])
            caches_v.append(kv[1])
    if cfg.attn_type == "mla":
        return x, {"c_kv": jnp.concatenate(caches_ckv, axis=0),
                   "k_rope": jnp.concatenate(caches_kr, axis=0)}
    return x, {"k": jnp.concatenate(caches_k, axis=0),
               "v": jnp.concatenate(caches_v, axis=0)}


# ---------------------------------------------------------------------------
# decode: one token, caches carried
# ---------------------------------------------------------------------------
def forward_decode(params, x, caches, pos, cfg, mcx: MeshCtx):
    """x: (B,1,d).  Returns (hidden, new_caches)."""
    groups = stack_groups(cfg)

    if cfg.family == "ssm":
        def body(h, xs):
            lp, st = xs
            hn = L.apply_norm(lp["ln"], h[:, 0], cfg)
            y, st = SSM.mamba1_step(lp["ssm"], hn, cfg, st)
            return h + y[:, None], st
        x, ssm_states = jax.lax.scan(body, x, (params["stacks"][0],
                                               caches["ssm"]))
        return x, {"ssm": ssm_states}

    if cfg.family == "hybrid":
        slots = hybrid_attn_slots(cfg)
        apply_flags = jnp.zeros((cfg.num_layers,), jnp.bool_).at[
            jnp.array(slots)].set(True)
        slot_idx = jnp.zeros((cfg.num_layers,), jnp.int32)
        for si, li in enumerate(slots):
            slot_idx = slot_idx.at[li].set(si)

        def body(carry, xs):
            h, kc, vc = carry
            lp, st, flag, si = xs
            hn = L.apply_norm(lp["ln"], h[:, 0], cfg)
            y, st = SSM.mamba2_step(lp["ssm"], hn, cfg, st)
            h = h + y[:, None]

            def with_attn(op):
                h, kc, vc = op
                cache = {"k": jax.lax.dynamic_index_in_dim(kc, si, 0, False),
                         "v": jax.lax.dynamic_index_in_dim(vc, si, 0, False)}
                y, cache = attn_block_decode(params["shared"], h, cache, pos,
                                             cfg, mcx)
                kc = jax.lax.dynamic_update_index_in_dim(kc, cache["k"], si, 0)
                vc = jax.lax.dynamic_update_index_in_dim(vc, cache["v"], si, 0)
                return y, kc, vc

            h, kc, vc = jax.lax.cond(flag, with_attn, lambda op: op,
                                     (h, kc, vc))
            return (h, kc, vc), st

        (x, kc, vc), ssm_states = jax.lax.scan(
            body, (x, caches["k"], caches["v"]),
            (params["stacks"][0], caches["ssm"], apply_flags, slot_idx))
        return x, {"ssm": ssm_states, "k": kc, "v": vc}

    # attention families
    new_k, new_v, new_ckv, new_kr = [], [], [], []
    off = 0
    for (kind, lo, hi), stacked in zip(groups, params["stacks"]):
        n = hi - lo
        if cfg.attn_type == "mla":
            sl = {"c_kv": caches["c_kv"][off:off + n],
                  "k_rope": caches["k_rope"][off:off + n]}
            def body(h, xs):
                lp, ckv, kr = xs
                y, cache = attn_block_decode(lp, h, {"c_kv": ckv, "k_rope": kr},
                                             pos, cfg, mcx)
                return y, (cache["c_kv"], cache["k_rope"])
            x, (ckv, kr) = jax.lax.scan(body, x, (stacked, sl["c_kv"],
                                                  sl["k_rope"]))
            new_ckv.append(ckv)
            new_kr.append(kr)
        else:
            sl = {"k": caches["k"][off:off + n], "v": caches["v"][off:off + n]}
            def body(h, xs):
                lp, k, v = xs
                y, cache = attn_block_decode(lp, h, {"k": k, "v": v}, pos,
                                             cfg, mcx)
                return y, (cache["k"], cache["v"])
            x, (k, v) = jax.lax.scan(body, x, (stacked, sl["k"], sl["v"]))
            new_k.append(k)
            new_v.append(v)
        off += n
    if cfg.attn_type == "mla":
        return x, {"c_kv": jnp.concatenate(new_ckv, axis=0),
                   "k_rope": jnp.concatenate(new_kr, axis=0)}
    return x, {"k": jnp.concatenate(new_k, axis=0),
               "v": jnp.concatenate(new_v, axis=0)}
