"""Model facade: builds step functions + shardings + abstract specs for every
architecture config.

Public API
----------
``build(cfg, mcx)`` returns a ``Model`` with:
  * ``init_params(rng)``            — real parameters (smoke tests, examples)
  * ``abstract_params()``           — ShapeDtypeStruct pytree (dry-run)
  * ``param_shardings()``           — NamedSharding pytree
  * ``train_step``                  — (params, opt_state, batch, step) -> ...
  * ``prefill_step``                — (params, batch) -> (tokens, caches)
  * ``decode_step``                 — (params, caches, token, pos) -> ...
  * ``input_specs(shape_cfg)``      — abstract inputs for each step kind
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models import transformer as T
from repro.models.layers import MeshCtx, pad_to
from repro.train import optimizer as OPT


# ---------------------------------------------------------------------------
# vocab-parallel embedding (shard_map: masked local gather + psum)
# ---------------------------------------------------------------------------
def embed(tokens, table, mcx: MeshCtx):
    """tokens (B,S) int32; table (V,d) sharded P(tp, None) -> (B,S,d)."""
    def inner(tok, tab):
        V_loc = tab.shape[0]
        lo = jax.lax.axis_index(mcx.tp) * V_loc
        idx = tok - lo
        ok = jnp.logical_and(idx >= 0, idx < V_loc)
        x = jnp.where(ok[..., None], tab[jnp.clip(idx, 0, V_loc - 1)], 0)
        return jax.lax.psum(x, mcx.tp)

    bs = mcx.bspec(tokens.shape[0])
    if table.shape[0] % mcx.tp_size:
        # vocab not divisible by TP: plain (replicated-table) gather
        return table[tokens]
    return shard_map(
        inner, mesh=mcx.mesh,
        in_specs=(P(bs, None), P(mcx.tp, None)),
        out_specs=P(bs, None, None),
    )(tokens, table)


# ---------------------------------------------------------------------------
# chunked vocab-parallel cross-entropy (never materializes (B,S,V))
# ---------------------------------------------------------------------------
def ce_loss(h, unemb_t, targets, mask, cfg, mcx: MeshCtx):
    """h: (B,S,d) final-normed; unemb_t: (V,d) [vocab-major]; targets (B,S).
    Returns (sum_loss, sum_mask)."""
    B, S, d = h.shape
    c = min(cfg.loss_chunk, S)
    if S % c:
        pad = c - S % c
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
        S = S + pad
    nc = S // c
    hc = jnp.moveaxis(h.reshape(B, nc, c, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, nc, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nc, c), 1, 0)

    V_pad = unemb_t.shape[0]
    pad_mask = (jnp.arange(V_pad) >= cfg.vocab_size)

    def chunk(carry, xs):
        hb, tb, mb = xs
        logits = jnp.einsum("bcd,vd->bcv", hb, unemb_t,
                            preferred_element_type=jnp.float32)
        logits = mcx.shard(logits, mcx.bspec(B), None, mcx.tp)
        logits = jnp.where(pad_mask, -1e30, logits)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        onehot = jax.nn.one_hot(tb, logits.shape[-1], dtype=logits.dtype)
        lab = jnp.sum(logits * onehot, axis=-1)
        loss = jnp.sum((lse - lab) * mb)
        return carry + loss, None

    total, _ = jax.lax.scan(chunk, jnp.zeros((), jnp.float32), (hc, tc, mc))
    return total, jnp.sum(mask)


def logits_fn(h, unemb_t, cfg, mcx):
    """Full logits for decode (h: (B,1,d)) -> (B,V) fp32."""
    logits = jnp.einsum("bsd,vd->bsv", h, unemb_t,
                        preferred_element_type=jnp.float32)
    pad_mask = (jnp.arange(unemb_t.shape[0]) >= cfg.vocab_size)
    return jnp.where(pad_mask, -1e30, logits[:, 0])


def _unemb_t(params, cfg):
    """Vocab-major unembedding matrix (V, d)."""
    if cfg.tie_embeddings:
        return params["emb"]
    return params["unemb"].T


# ---------------------------------------------------------------------------
# Model bundle
# ---------------------------------------------------------------------------
@dataclass
class Model:
    cfg: ModelConfig
    mcx: MeshCtx
    opt_cfg: OPT.OptConfig

    # ---------------- params ------------------------------------------------
    def init_params(self, rng):
        return T.init_stack(self.cfg, rng, self.mcx)

    def abstract_params(self):
        return jax.eval_shape(
            lambda r: T.init_stack(self.cfg, r, self.mcx),
            jax.ShapeDtypeStruct((2,), jnp.uint32))

    def param_specs(self):
        ap = self.abstract_params()
        return tree_param_specs(ap, self.cfg, self.mcx)

    def param_shardings(self):
        return jax.tree.map(lambda s: NamedSharding(self.mcx.mesh, s),
                            self.param_specs())

    def abstract_opt_state(self):
        return jax.eval_shape(
            lambda p: OPT.init_opt_state(p, self.opt_cfg),
            self.abstract_params())

    def opt_shardings(self):
        specs = self.param_specs()
        shapes = jax.tree.map(lambda x: x.shape, self.abstract_params())
        return OPT.opt_state_shardings(specs, shapes, self.mcx, self.opt_cfg)

    # ---------------- embedding / io ---------------------------------------
    def _embed_inputs(self, params, batch):
        cfg, mcx = self.cfg, self.mcx
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(jnp.dtype(cfg.dtype))
        else:
            x = embed(batch["tokens"], params["emb"], mcx)
        return mcx.shard(x, mcx.dp, None, None)

    # ---------------- train step -------------------------------------------
    def loss_fn(self, params, batch):
        cfg, mcx = self.cfg, self.mcx
        x = self._embed_inputs(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, aux = T.forward_train(params, x, cfg, mcx, positions)
        h = L.apply_norm(params["ln_final"], h, cfg)
        unemb_t = _unemb_t(params, cfg)
        labels = batch["labels"]
        mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
        total, denom = ce_loss(h, unemb_t, labels, mask, cfg, mcx)
        loss = total / jnp.maximum(denom, 1.0)

        if cfg.mtp_depth and "mtp" in params and cfg.input_mode == "tokens":
            # multi-token prediction: predict t+2 from [h_t ; emb(label_t)]
            mp = params["mtp"]
            e_next = embed(labels, params["emb"], mcx)
            hcat = jnp.concatenate(
                [L.apply_norm(mp["ln_h"], h, cfg),
                 L.apply_norm(mp["ln_e"], e_next, cfg)], axis=-1)
            h2 = jnp.einsum("bsd,de->bse", hcat, mp["proj"])
            y = T.attn_block_fwd(mp["layer"], h2, cfg, mcx, positions,
                                 causal=True)
            y = y[0] if isinstance(y, tuple) else y
            labels2 = jnp.roll(labels, -1, axis=1)
            mask2 = mask.at[:, -1].set(0.0)
            t2, d2 = ce_loss(L.apply_norm(params["ln_final"], y, cfg),
                             unemb_t, labels2, mask2, cfg, mcx)
            loss = loss + 0.3 * t2 / jnp.maximum(d2, 1.0)

        loss = loss + aux
        return loss, {"ce": total / jnp.maximum(denom, 1.0)}

    def train_step(self, params, opt_state, batch, step):
        cfg = self.cfg
        M = cfg.microbatches
        if M == 1:
            (loss, met), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, batch)
        else:
            # fp32 grad accumulator is ZeRO-sharded over DP (reduce-scatter
            # per microbatch instead of holding a TP-only-sharded replica)
            specs = self.param_specs()
            shapes = jax.tree.map(lambda x: x.shape, self.abstract_params())
            acc_sh = jax.tree.map(
                lambda s, sh: NamedSharding(
                    self.mcx.mesh,
                    OPT.zero1_spec(s, sh, self.mcx.dp, self.mcx.dp_size)),
                specs, shapes)

            def micro(carry, mb):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(
                    self.loss_fn, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b, sh: jax.lax.with_sharding_constraint(
                        a + b.astype(jnp.float32), sh),
                    gacc, g, acc_sh)
                return (gacc, lacc + l), None

            g0 = jax.tree.map(
                lambda p, sh: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), sh), params, acc_sh)
            mbs = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]), batch)
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / M, grads)
            loss = loss / M
            met = {"ce": loss}

        new_params, new_opt, stats = OPT.apply_updates(
            grads, opt_state, params, step, self.opt_cfg)
        metrics = {"loss": loss, **met, **stats}
        return new_params, new_opt, metrics

    # ---------------- prefill / decode -------------------------------------
    def prefill_step(self, params, batch):
        cfg, mcx = self.cfg, self.mcx
        x = self._embed_inputs(params, batch)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h, caches = T.forward_prefill(params, x, cfg, mcx, positions)
        h = L.apply_norm(params["ln_final"], h, cfg)
        logits = logits_fn(h[:, -1:], _unemb_t(params, cfg), cfg, mcx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    def decode_step(self, params, caches, token, pos):
        """token: (B,) int32 (or (B,1,d) embeddings); pos: scalar int32."""
        cfg, mcx = self.cfg, self.mcx
        if cfg.input_mode == "embeddings":
            x = token.astype(jnp.dtype(cfg.dtype))
        else:
            x = embed(token[:, None], params["emb"], mcx)
        h, caches = T.forward_decode(params, x, caches, pos, cfg, mcx)
        h = L.apply_norm(params["ln_final"], h, cfg)
        logits = logits_fn(h, _unemb_t(params, cfg), cfg, mcx)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    # ---------------- abstract inputs ---------------------------------------
    def input_specs(self, shape: ShapeConfig):
        cfg, mcx = self.cfg, self.mcx
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        if shape.kind == "train":
            batch = {"labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.input_mode == "embeddings":
                batch["embeddings"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            return {"batch": batch}
        if shape.kind == "prefill":
            batch = {}
            if cfg.input_mode == "embeddings":
                batch["embeddings"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)
            else:
                batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
            return {"batch": batch}
        # decode
        caches = self.cache_specs(shape)
        if cfg.input_mode == "embeddings":
            token = jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)
        else:
            token = jax.ShapeDtypeStruct((B,), i32)
        return {"caches": caches, "token": token,
                "pos": jax.ShapeDtypeStruct((), i32)}

    def batch_shardings(self, specs):
        mcx = self.mcx

        def shard_of(path_leaf):
            ndim = len(path_leaf.shape)
            if ndim == 0:
                return NamedSharding(mcx.mesh, P())
            bs = mcx.bspec(path_leaf.shape[0])
            return NamedSharding(mcx.mesh, P(bs, *([None] * (ndim - 1))))
        return jax.tree.map(shard_of, specs)

    # ---------------- caches -------------------------------------------------
    def cache_specs(self, shape: ShapeConfig):
        cfg, mcx = self.cfg, self.mcx
        B, S = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        Lr = cfg.num_layers
        if cfg.family == "ssm":
            K, di, N = cfg.ssm_conv, cfg.d_inner, cfg.ssm_state
            return {"ssm": (jax.ShapeDtypeStruct((Lr, B, K - 1, di), dt),
                            jax.ShapeDtypeStruct((Lr, B, di, N), jnp.float32))}
        if cfg.family == "hybrid":
            K = cfg.ssm_conv
            conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
            n_slots = len(T.hybrid_attn_slots(cfg))
            return {
                "ssm": (jax.ShapeDtypeStruct((Lr, B, K - 1, conv_dim), dt),
                        jax.ShapeDtypeStruct(
                            (Lr, B, cfg.ssm_nheads, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)),
                "k": jax.ShapeDtypeStruct(
                    (n_slots, B, S, cfg.num_kv_heads, cfg.head_dim), dt),
                "v": jax.ShapeDtypeStruct(
                    (n_slots, B, S, cfg.num_kv_heads, cfg.head_dim), dt),
            }
        if cfg.attn_type == "mla":
            return {
                "c_kv": jax.ShapeDtypeStruct((Lr, B, S, cfg.kv_lora_rank), dt),
                "k_rope": jax.ShapeDtypeStruct((Lr, B, S, cfg.qk_rope_dim), dt),
            }
        return {
            "k": jax.ShapeDtypeStruct(
                (Lr, B, S, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct(
                (Lr, B, S, cfg.num_kv_heads, cfg.head_dim), dt),
        }

    def cache_shardings(self, shape: ShapeConfig):
        cfg, mcx = self.cfg, self.mcx
        bs = mcx.bspec(shape.global_batch)

        def rule(leaf):
            nd = len(leaf.shape)
            if nd == 4 and cfg.family == "ssm":
                # (L,B,K-1,di) conv or (L,B,di,N) state: shard di over tp
                if leaf.shape[-1] == cfg.d_inner:
                    return P(None, bs, None, mcx.tp)
                return P(None, bs, mcx.tp, None)
            if nd == 5:   # (L,B,S,KV,hd) attention cache -> seq-shard over tp
                return P(None, bs, mcx.tp, None, None)
            if nd == 4:   # (L,B,S,kvr) mla cache / hybrid conv
                if cfg.attn_type == "mla":
                    return P(None, bs, mcx.tp, None)
                return P(None, bs, None, None)
            return P(*([None] + [bs] + [None] * (nd - 2)))

        specs = self.cache_specs(shape)

        def to_sharding(leaf):
            return NamedSharding(mcx.mesh, rule(leaf))
        return jax.tree.map(to_sharding, specs)


# ---------------------------------------------------------------------------
# parameter sharding rules (by tree path)
# ---------------------------------------------------------------------------
def _spec_for_leaf(path_names, full_shape, cfg, mcx) -> P:
    tp = mcx.tp
    tp_size = mcx.tp_size
    name = path_names[-1]
    in_moe = "moe" in path_names
    in_ssm = "ssm" in path_names
    # leaves under "stacks" carry a leading layer dim: apply rules to shape[1:]
    stacked = "stacks" in path_names
    leaf_shape = full_shape[1:] if stacked else full_shape
    nd = len(full_shape)

    def fits(dim):
        return leaf_shape[dim] % tp_size == 0

    base: Optional[tuple] = None
    if name == "emb":
        base = (tp, None) if fits(0) else (None, None)
    elif name == "unemb":
        base = (None, tp) if fits(1) else (None, None)
    elif name in ("wq", "wk", "wv"):
        base = (None, tp, None) if fits(1) else (None, None, None)
    elif name == "wo":
        base = (tp, None, None) if fits(0) else (None, None, None)
    elif name in ("bq",):
        base = (tp, None) if fits(0) else (None, None)
    elif name in ("bk", "bv"):
        base = (None, None)
    elif name in ("wq_b", "wk_b", "wv_b"):
        base = (None, tp, None) if fits(1) else (None, None, None)
    elif name in ("wq_a", "wkv_a"):
        base = (None, None)
    elif name in ("w_gate", "w_up"):
        if in_moe:  # (E, d, ff): shard experts
            base = (tp, None, None) if fits(0) else (None, None, None)
        else:
            base = (None, tp) if fits(1) else (None, None)
    elif name == "w_down":
        if in_moe:
            base = (tp, None, None) if fits(0) else (None, None, None)
        else:
            base = (tp, None) if fits(0) else (None, None)
    elif name in ("ws_gate", "ws_up"):
        base = (None, tp) if fits(1) else (None, None)
    elif name == "ws_down":
        base = (tp, None) if fits(0) else (None, None)
    elif name == "b_up":
        base = (tp,) if fits(0) else (None,)
    elif name == "router":
        base = (None, None)
    elif in_ssm and cfg.ssm_version == 1:
        if name == "in_proj":
            base = (None, tp) if fits(1) else (None, None)
        elif name == "conv_w":
            base = (None, tp) if fits(1) else (None, None)
        elif name in ("conv_b", "dt_bias", "D"):
            base = (tp,) if fits(0) else (None,)
        elif name in ("x_proj", "A_log", "out_proj"):
            base = (tp, None) if fits(0) else (None, None)
        elif name == "dt_proj":
            base = (None, tp) if fits(1) else (None, None)
    elif in_ssm and cfg.ssm_version == 2:
        # mamba2 projections have heterogeneous concat segments: replicate
        base = tuple([None] * nd)

    if base is None:
        base = tuple([None] * len(leaf_shape))
    # stacked layers: leading layer dim is never sharded
    if len(base) < nd:
        base = tuple([None] * (nd - len(base))) + base
    # FSDP (ZeRO-3): additionally shard the largest unsharded dim over DP;
    # GSPMD re-gathers each layer's slice inside the scan body on use.
    if cfg.fsdp and nd >= 2:
        from repro.train.optimizer import zero1_spec
        return zero1_spec(P(*base), full_shape, mcx.dp, mcx.dp_size)
    return P(*base)


def tree_param_specs(abstract_params, cfg, mcx):
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t) if not isinstance(node, tuple) else tuple(t)
        return _spec_for_leaf(path, node.shape, cfg, mcx)
    return walk(abstract_params, ())


# ---------------------------------------------------------------------------
# builder
# ---------------------------------------------------------------------------
def build(cfg: ModelConfig, mcx: MeshCtx,
          opt_cfg: Optional[OPT.OptConfig] = None) -> Model:
    oc = opt_cfg or OPT.OptConfig(grad_compress=cfg.grad_compress)
    return Model(cfg=cfg, mcx=mcx, opt_cfg=oc)
