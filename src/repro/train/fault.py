"""Fault-tolerance utilities: preemption handling, restart, straggler
monitoring.

At 1000+-node scale the failure model is: (a) planned preemption (SIGTERM
from the scheduler) — checkpoint immediately and exit cleanly; (b) node
loss — the job restarts from the latest checkpoint with a possibly different
device count (handled by CheckpointManager's elastic restore); (c)
stragglers — synchronous collectives make the step time the max over hosts;
the ``StepTimer`` flags outlier steps so orchestration can replace the slow
host (on TPU, real deployments also set megascale flags for timeout-based
barrier recovery; documented in README).
"""
from __future__ import annotations

import signal
import time
from collections import deque
from typing import Optional


class PreemptionGuard:
    """Installs SIGTERM/SIGINT handlers that set a flag the train loop polls
    at step boundaries (never mid-collective).

    ``chain=True`` keeps any previously-installed Python handler live: the
    guard sets its flag and then forwards the signal, so a library-level
    guard (e.g. the engine checkpointer's) composes with an application's
    own handler instead of silently replacing it."""

    def __init__(self, signals=(signal.SIGTERM,), chain: bool = False):
        self.requested = False
        self.chain = chain
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._handler)
            except ValueError:
                pass   # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True
        if self.chain:
            prev = self._prev.get(signum)
            if callable(prev):
                prev(signum, frame)

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


class StepTimer:
    """Tracks step latencies; exposes a straggler verdict (p50-based)."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.times = deque(maxlen=window)
        self.threshold = threshold
        self._t0: Optional[float] = None
        self.stragglers = 0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if len(self.times) >= 10:
            med = sorted(self.times)[len(self.times) // 2]
            if dt > self.threshold * med:
                self.stragglers += 1
        self.times.append(dt)

    @property
    def median(self):
        if not self.times:
            return 0.0
        return sorted(self.times)[len(self.times) // 2]
