"""Checkpointing: async host-side save, elastic reshard-on-restore.

* ``save(path, step, tree)`` — gathers leaves to host and writes an .npz +
  manifest; the write happens on a background thread (training continues).
* ``restore(path, abstract_tree, shardings)`` — loads the newest step and
  ``device_put``s each leaf with the *target* shardings, which may belong to
  a different mesh shape than the one that saved it (elastic scaling: the
  checkpoint is mesh-agnostic host data).
* ``latest_step(path)`` — resume discovery.

The manifest also carries the data-pipeline state so input streams resume
deterministically.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Optional

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [v for _, v in flat]
    return names, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "MANIFEST.json")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None,
             blocking: bool = False):
        """Gather to host, then write asynchronously."""
        self.wait()
        names, leaves, _ = _flatten_with_names(tree)
        # device->host gather; npz has no bf16 support: upcast to f32
        def to_host(l):
            h = np.asarray(l)
            if h.dtype not in (np.float64, np.float32, np.float16, np.int64,
                               np.int32, np.int16, np.int8, np.uint32,
                               np.uint8, np.bool_):
                h = h.astype(np.float32)
            return h
        host = [to_host(l) for l in leaves]

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": h for i, h in enumerate(host)})
            manifest = {"step": step, "names": names,
                        "time": time.time(), "extra": extra or {}}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _gc(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, abstract_tree, shardings=None,
                step: Optional[int] = None):
        """Returns (tree, extra).  ``shardings`` (same structure) places each
        leaf on the *current* mesh — elastic resharding is implicit."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        names, abs_leaves, treedef = _flatten_with_names(abstract_tree)
        assert names == manifest["names"], "checkpoint/tree structure mismatch"
        sh_leaves = None
        if shardings is not None:
            _, sh_leaves, _ = _flatten_with_names(shardings)
        out = []
        for i, (name, ab) in enumerate(zip(names, abs_leaves)):
            h = data[f"a{i}"]
            assert tuple(h.shape) == tuple(ab.shape), (name, h.shape, ab.shape)
            if sh_leaves is not None:
                arr = jax.device_put(h, sh_leaves[i])
            else:
                arr = jax.device_put(h)
            if arr.dtype != ab.dtype:
                arr = arr.astype(ab.dtype)   # e.g. f32 -> bf16 back-cast
            out.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest.get("extra", {})
