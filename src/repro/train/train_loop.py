"""Training loop: jit'd step, periodic async checkpointing, preemption-safe
exit, resumption (incl. data-pipeline state), straggler timing."""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.fault import PreemptionGuard, StepTimer
from repro.train import optimizer as OPT


def train(model, data, *, steps: int, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 100, log_every: int = 10,
          resume: bool = True, log: Callable = print):
    """model: repro.models.model.Model; data: pipeline with .next()/.state()."""
    mcx = model.mcx
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    opt_state = OPT.init_opt_state(params, model.opt_cfg)
    start_step = 0

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore(
            (model.abstract_params(), model.abstract_opt_state()))
        start_step = int(extra.get("step", 0))
        if "data_state" in extra:
            data.restore(extra["data_state"])
        log(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(model.train_step, donate_argnums=(0, 1))
    guard = PreemptionGuard()
    timer = StepTimer()
    losses = []
    for step in range(start_step, steps):
        batch = {k: jnp.asarray(v) for k, v in data.next().items()}
        with timer:
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
        if step % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            losses.append((step, loss))
            log(f"[train] step={step} loss={loss:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} "
                f"med_step={timer.median*1e3:.0f}ms "
                f"stragglers={timer.stragglers}")
        should_ckpt = mgr and (step + 1) % ckpt_every == 0
        if mgr and (should_ckpt or guard.requested or step == steps - 1):
            mgr.save(step + 1, (params, opt_state),
                     extra={"step": step + 1, "data_state": data.state()},
                     blocking=guard.requested or step == steps - 1)
        if guard.requested:
            log(f"[train] preemption at step {step}: checkpointed, exiting")
            break
    guard.restore()
    if mgr:
        mgr.wait()
    return params, opt_state, losses
