"""AdamW with fp32 master weights, ZeRO-1 style DP-sharded optimizer state,
global-norm clipping, warmup+cosine schedule, and optional int8-compressed
update all-gather with error feedback.

The optimizer is pure-functional: ``init(params) -> state``,
``apply(grads, state, params, step) -> (new_params, new_state, stats)``.
ZeRO-1 is realized through *shardings*: the state pytree gets NamedShardings
that additionally shard the largest dimension over the DP axes, which makes
XLA emit reduce-scatter for gradients and all-gather for updated parameters.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_compress: bool = False     # int8 update all-gather w/ error feedback


def schedule(oc: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params, oc: OptConfig):
    # copy=True: fp32 leaves must not alias the live params (donation safety)
    master = jax.tree.map(lambda p: jnp.array(p, jnp.float32, copy=True),
                          params)
    mu = jax.tree.map(jnp.zeros_like, master)
    nu = jax.tree.map(jnp.zeros_like, master)
    state = {"mu": mu, "nu": nu, "master": master}
    if oc.grad_compress:
        state["err"] = jax.tree.map(jnp.zeros_like, master)
    return state


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _quantize_int8(x):
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def apply_updates(grads, state, params, step, oc: OptConfig):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(oc, step)
    b1, b2 = oc.beta1, oc.beta2
    bc1 = 1 - b1 ** (step.astype(jnp.float32) + 1)
    bc2 = 1 - b2 ** (step.astype(jnp.float32) + 1)

    def upd(g, mu, nu, m):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        mhat = mu / bc1
        vhat = nu / bc2
        delta = -lr * (mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * m)
        return mu, nu, m + delta, delta

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    flat_ms = treedef.flatten_up_to(state["master"])
    out = [upd(g, mu, nu, m) for g, mu, nu, m in
           zip(flat_g, flat_mu, flat_nu, flat_ms)]
    new_mu = treedef.unflatten([o[0] for o in out])
    new_nu = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])
    deltas = treedef.unflatten([o[3] for o in out])

    new_state = {"mu": new_mu, "nu": new_nu, "master": new_master}

    if oc.grad_compress:
        # int8 error-feedback compression of the parameter *update*: the
        # (ZeRO-sharded) delta is quantized before the implicit all-gather back
        # to the bf16 replica, halving ZeRO all-gather bytes vs bf16.
        err = state["err"]

        def comp(d, e, p):
            d_ef = d + e
            q, s = _quantize_int8(d_ef)
            dq = q.astype(jnp.float32) * s
            return dq, d_ef - dq

        flat_d = jax.tree.leaves(deltas)
        flat_e = treedef.flatten_up_to(err)
        flat_p = jax.tree.leaves(params)
        comp_out = [comp(d, e, p) for d, e, p in zip(flat_d, flat_e, flat_p)]
        deltas = treedef.unflatten([c[0] for c in comp_out])
        new_state["err"] = treedef.unflatten([c[1] for c in comp_out])

    new_params = jax.tree.map(
        lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
        params, deltas)
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of optimizer state
# ---------------------------------------------------------------------------
def zero1_spec(spec: P, shape, dp_axes: tuple, dp_size: int) -> P:
    """Extend a parameter PartitionSpec so the largest unsharded dim is also
    sharded over the DP axes (if divisible).  No-op if the spec already uses
    a DP axis (e.g. FSDP-sharded parameters)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if used & set(dp_axes):
        return P(*entries)
    # choose the largest dim that is unsharded and divisible
    best, best_dim = -1, None
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and n % dp_size == 0 and n > best:
            best, best_dim = n, i
    if best_dim is None:
        return P(*entries)
    entries[best_dim] = tuple(dp_axes)
    return P(*entries)


def opt_state_shardings(param_specs, param_shapes, mcx, oc: OptConfig):
    """Build NamedShardings for the optimizer state from parameter specs."""
    def f(spec, shape):
        zspec = zero1_spec(spec, shape, mcx.dp, mcx.dp_size)
        return NamedSharding(mcx.mesh, zspec)
    one = jax.tree.map(f, param_specs, param_shapes)
    out = {"mu": one, "nu": one, "master": one}
    if oc.grad_compress:
        out["err"] = one
    return out
